"""Continuous batching: per-step join/leave of the decode batch.

The request-lifecycle layer of the serving stack, sitting between
``serve.engine`` (compiled step fns over packed weights) and
``serve.kvcache`` (paged session storage):

            submit() ──> queue ──(admission: free slot + pages)──┐
                                                                 v
       prefill_session (B=1, prompt bucketed pow2, n_valid traced)
                │ store prompt KV into pages
                v
       join: gather pages ─> working-cache row b, t[b]=len, tok[b]
                │
                v                        ┌── leave (done): free pages
       decode_chunk (n_steps per dispatch) ──┤   or sync row ─> pages
                │                        └── swap-remove compaction
                └── repeat

**Shape discipline** — nothing recompiles in steady state:

* prompts right-pad to a pow2 bucket; ``n_valid`` is traced, so one
  prefill jit per bucket (≤ log2(capacity) programs);
* the decode working cache is a FIXED (max_batch, capacity) dense
  cache; chunks run on its leading pow2 bucket of rows
  (``bucket_batch=False`` pins the full width — the bitwise-repro
  test mode), giving ≤ log2(max_batch) chunk programs;
* join/leave are jitted row scatters with a *traced* slot index, and
  sessions swap-remove so live rows stay compact at the front.

**Sessions.** A request with ``keep=True`` leaves its pages allocated on
completion; a later ``submit(None, n, session=sid)`` rejoins exactly
where it left off (tokens replay bitwise at the same batch width: the
PRNG key of position p is ``fold_in(seed, p)`` regardless of when — or
next to whom — p is decoded; see ``serve.sampling``). ``release(sid)``
frees a kept session.

**Work accounting.** Each ``step()`` interleaves up to
``prefill_budget`` admissions with one decode chunk, and returns the
step's events (new tokens per request, completions) so a load generator
can timestamp TTFT / per-token latency without reaching inside.
Mid-chunk finishers overshoot (the chunk length is static); the surplus
tokens are discarded — the waste is bounded by ``decode_chunk`` and is
the price of a never-recompiling decode loop.

MoE caveat: expert-capacity competition couples batch rows, so batched
MoE decode is not bitwise identical to solo decode (dense models are).
The scheduler serves MoE fine; the bitwise guarantee is dense-only.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.transformer import DecodeCache

from . import sampling as sampling_lib
from .engine import ServeEngine, next_pow2
from .kvcache import PagedKVCache


@dataclasses.dataclass
class Completion:
    """One finished request."""

    rid: int
    session: object
    tokens: np.ndarray            # (n_new,) int32 generated tokens
    prompt_len: int
    n_new: int
    kept: bool                    # pages still allocated (resumable)


@dataclasses.dataclass
class StepEvents:
    """What one ``step()`` did — the load generator's measurement hooks."""

    prefilled: list               # rids whose first token appeared
    tokens: dict                  # rid -> [new token ids] this step
    completed: list               # Completion
    n_active: int
    n_queued: int


@dataclasses.dataclass
class _Slot:
    rid: int
    sid: object
    samp: sampling_lib.SamplingParams
    rem: int                      # tokens still to emit
    t_true: int                   # real KV length (graph t may overshoot)
    emitted: list
    keep: bool
    prompt_len: int


@partial(jax.jit, donate_argnums=0)
def _write_slot(cache, b, k, v, pos, t, tok, toks_all):
    """Install a session into working-cache row ``b`` (traced index)."""
    kv = cache.kv
    kv = attn.KVCache(kv.k.at[:, b].set(k.astype(kv.k.dtype)),
                      kv.v.at[:, b].set(v.astype(kv.v.dtype)),
                      kv.pos.at[:, b].set(
                          jnp.broadcast_to(pos, kv.pos.shape[::2])),
                      kv.rolling)
    return (DecodeCache(kv=kv, cross_kv=None, t=cache.t.at[b].set(t)),
            toks_all.at[b].set(tok))


@partial(jax.jit, donate_argnums=0)
def _move_slot(cache, src, dst, toks_all):
    """Swap-remove compaction: copy row ``src`` over row ``dst``."""
    kv = cache.kv
    kv = attn.KVCache(kv.k.at[:, dst].set(kv.k[:, src]),
                      kv.v.at[:, dst].set(kv.v[:, src]),
                      kv.pos.at[:, dst].set(kv.pos[:, src]), kv.rolling)
    return (DecodeCache(kv=kv, cross_kv=None,
                        t=cache.t.at[dst].set(cache.t[src])),
            toks_all.at[dst].set(toks_all[src]))


@jax.jit
def _read_slot(cache, b):
    return cache.kv.k[:, b], cache.kv.v[:, b]


class ContinuousScheduler:
    """Continuous-batching scheduler over a ``ServeEngine``.

    Args:
        engine: the packed-weight engine (dense decoder-only models).
        max_batch: decode slots (power of two).
        capacity: per-slot token capacity (prompt + output; power of
            two, multiple of ``page_size``).
        page_size: tokens per KV page.
        n_pages: page-pool size; default backs every slot at full
            capacity (kept sessions beyond that need headroom — pass
            more).
        prefill_budget: admissions attempted per step before the decode
            chunk — the prefill/decode interleaving knob.
        decode_chunk: decode steps per dispatch.
        bucket_batch: run chunks on the pow2 bucket of live rows (True,
            the throughput mode) or always at ``max_batch`` (False —
            fixed shapes, the bitwise-reproducibility mode).
        max_queue: admission control — ``submit`` beyond this many
            waiting requests raises.
    """

    def __init__(self, engine: ServeEngine, *, max_batch: int = 8,
                 capacity: int = 256, page_size: int = 16,
                 n_pages: int | None = None, prefill_budget: int = 1,
                 decode_chunk: int = 8, bucket_batch: bool = True,
                 max_queue: int = 1024):
        engine._require_continuous()
        if max_batch & (max_batch - 1):
            raise ValueError(f"max_batch must be a power of two, "
                             f"got {max_batch}")
        if page_size & (page_size - 1):
            raise ValueError(f"page_size must be a power of two, "
                             f"got {page_size}")
        if capacity % page_size:
            raise ValueError(f"capacity {capacity} not divisible by "
                             f"page size {page_size}")
        self.engine = engine
        self.cfg = engine.cfg
        self.max_batch = max_batch
        self.capacity = capacity
        self.page_size = page_size
        self.prefill_budget = max(prefill_budget, 1)
        self.decode_chunk = max(decode_chunk, 1)
        self.bucket_batch = bucket_batch
        self.max_queue = max_queue
        if n_pages is None:
            n_pages = max_batch * capacity // page_size
        self.pool = PagedKVCache(self.cfg, n_pages=n_pages,
                                 page_size=page_size, mesh=engine.mesh)
        # fixed-shape working cache; the scalar clock becomes per-row
        cache = engine.api.init_cache(engine.params, max_batch, capacity)
        self.cache = cache._replace(t=jnp.zeros((max_batch,), jnp.int32))
        self._toks = jnp.zeros((max_batch,), jnp.int32)
        self.slots: list[_Slot] = []          # compact: rows [0, n_active)
        self.queue: collections.deque = collections.deque()
        self._sessions: dict = {}             # sid -> next token (int)
        self._next_rid = 0
        self._samp = {
            "temp": np.zeros((max_batch,), np.float32),
            "top_p": np.ones((max_batch,), np.float32),
            "top_k": np.zeros((max_batch,), np.int32),
            "seed": np.zeros((max_batch,), np.uint32),
        }

    # -- request intake -----------------------------------------------------

    def submit(self, prompt, max_new: int, *,
               sampling: sampling_lib.SamplingParams = sampling_lib.GREEDY,
               session=None, keep: bool = False) -> int:
        """Queue a request; returns its rid.

        ``prompt=None`` resumes a kept session (``session`` required):
        generation continues from the session's stored state, replaying
        the exact token stream a single longer request would produce.
        """
        if len(self.queue) >= self.max_queue:
            raise RuntimeError(f"admission refused: {self.max_queue} "
                               "requests already queued")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        sampling.validate()
        if prompt is None:
            if session not in self._sessions:
                raise KeyError(f"unknown or released session {session!r}")
            need = self.pool.length(session) + max_new
        else:
            prompt = np.asarray(prompt, np.int32).reshape(-1)
            if len(prompt) < 1:
                raise ValueError("empty prompt")
            need = len(prompt) + max_new
        if need > self.capacity:
            raise ValueError(f"request needs {need} cache slots, capacity "
                             f"is {self.capacity}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, prompt, max_new, sampling, session, keep))
        return rid

    def release(self, session) -> None:
        """Free a kept session's pages (it can no longer be resumed)."""
        del self._sessions[session]
        self.pool.free(session)

    # -- lifecycle internals ------------------------------------------------

    def _join(self, slot: _Slot, tok: int) -> None:
        b = len(self.slots)
        k, v, pos, length = self.pool.load(slot.sid, self.capacity)
        self.cache, self._toks = _write_slot(
            self.cache, jnp.int32(b), k, v, pos, jnp.int32(length),
            jnp.int32(tok), self._toks)
        for name, val in zip(self._samp,
                             (slot.samp.temperature, slot.samp.top_p,
                              slot.samp.top_k, slot.samp.seed)):
            self._samp[name][b] = val
        self.slots.append(slot)

    def _leave(self, b: int) -> Completion:
        slot = self.slots[b]
        if slot.keep:
            k, v = _read_slot(self.cache, jnp.int32(b))
            self.pool.store(slot.sid, k, v, slot.t_true)
            self._sessions[slot.sid] = int(slot.emitted[-1])
        else:
            self.pool.free(slot.sid)
            self._sessions.pop(slot.sid, None)
        last = len(self.slots) - 1
        if b != last:
            self.cache, self._toks = _move_slot(
                self.cache, jnp.int32(last), jnp.int32(b), self._toks)
            for arr in self._samp.values():
                arr[b] = arr[last]
            self.slots[b] = self.slots[last]
        self.slots.pop()
        return Completion(rid=slot.rid, session=slot.sid,
                          tokens=np.asarray(slot.emitted, np.int32),
                          prompt_len=slot.prompt_len,
                          n_new=len(slot.emitted), kept=slot.keep)

    def _admit_one(self, events: StepEvents) -> bool:
        """Try to prefill+join the queue head; False if it must wait."""
        if not self.queue or len(self.slots) >= self.max_batch:
            return False
        rid, prompt, max_new, samp, session, keep = self.queue[0]
        if prompt is None:                       # resume a kept session
            kv_len = self.pool.length(session)
            try:
                self.pool.extend(session, kv_len + max_new)
            except MemoryError:
                return False                     # wait for pages
            self.queue.popleft()
            tok = self._sessions[session]
            slot = _Slot(rid=rid, sid=session, samp=samp, rem=max_new,
                         t_true=kv_len, emitted=[], keep=keep,
                         prompt_len=kv_len)
            self._join(slot, tok)
            return True
        S = len(prompt)
        sid = session if session is not None else ("r", rid)
        if not self.pool.can_admit(S + max_new):
            return False                         # wait for pages
        self.queue.popleft()
        self.pool.alloc(sid, S + max_new)
        s_bucket = min(max(self.page_size, next_pow2(S)), self.capacity)
        padded = np.zeros((1, s_bucket), np.int32)
        padded[0, :S] = prompt
        tok0, k, v = self.engine.prefill_session(
            jnp.asarray(padded), S, sampling_lib.params_arrays([samp]))
        self.pool.store(sid, k, v, S)
        tok0 = int(tok0[0])
        slot = _Slot(rid=rid, sid=sid, samp=samp, rem=max_new - 1,
                     t_true=S, emitted=[tok0], keep=keep, prompt_len=S)
        events.prefilled.append(rid)
        events.tokens.setdefault(rid, []).append(tok0)
        if slot.rem == 0:
            # single-token request: never joins the decode batch — its
            # pages already hold exactly the prompt KV, so there is no
            # working row to sync back (and nothing to free but pages)
            if keep:
                self._sessions[sid] = tok0
            else:
                self.pool.free(sid)
            events.completed.append(Completion(
                rid=rid, session=sid, tokens=np.asarray([tok0], np.int32),
                prompt_len=S, n_new=1, kept=keep))
        else:
            self._join(slot, tok0)
        return True

    # -- the step loop ------------------------------------------------------

    def step(self) -> StepEvents:
        """One scheduler step: up to ``prefill_budget`` admissions, then
        one decode chunk over the live rows."""
        events = StepEvents(prefilled=[], tokens={}, completed=[],
                            n_active=0, n_queued=0)
        for _ in range(self.prefill_budget):
            if not self._admit_one(events):
                break
        n_active = len(self.slots)
        if n_active:
            bucket = min(next_pow2(n_active), self.max_batch) \
                if self.bucket_batch else self.max_batch
            active = jnp.arange(self.max_batch) < n_active
            samp = {k: jnp.asarray(v) for k, v in self._samp.items()}
            toks, self.cache = self.engine.decode_chunk(
                self._toks, self.cache, active, samp,
                n_steps=self.decode_chunk, bucket=bucket)
            self._toks = self._toks.at[:bucket].set(toks[-1])
            host = np.asarray(toks)              # (n_steps, bucket)
            for b, slot in enumerate(self.slots):
                m = min(self.decode_chunk, slot.rem)
                new = host[:m, b].tolist()
                slot.emitted.extend(new)
                slot.rem -= m
                slot.t_true += m
                events.tokens.setdefault(slot.rid, []).extend(new)
            # leave in reverse so swap-remove never disturbs an earlier
            # finished row we have yet to process
            for b in range(len(self.slots) - 1, -1, -1):
                if self.slots[b].rem == 0:
                    events.completed.append(self._leave(b))
        events.n_active = len(self.slots)
        events.n_queued = len(self.queue)
        return events

    @property
    def idle(self) -> bool:
        return not self.queue and not self.slots

    def run_until_idle(self, max_steps: int = 100_000) -> dict:
        """Drain queue + batch; returns {rid: Completion}."""
        done: dict = {}
        for _ in range(max_steps):
            if self.idle:
                return done
            for c in self.step().completed:
                done[c.rid] = c
        raise RuntimeError(f"not idle after {max_steps} steps "
                           f"({len(self.queue)} queued, "
                           f"{len(self.slots)} active)")
