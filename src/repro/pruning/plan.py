"""Pruning plans: resolve a recipe against a model before spending FLOPs.

``plan_pruning(api, params, recipe, mesh=...)`` maps every enumerated
``SiteSpec`` through the recipe's first-match resolution and precomputes,
per group, what executing it will cost and which engine path it will take:

* ``batched``      — one vmapped jit over the stacked group (no mesh);
* ``rows-sharded`` — ``distributed.refine_rows_sharded`` (G replicated);
* ``gram-sharded`` — column-sharded G past ``gram_budget_bytes``;
* ``single-device``— mesh requested but the method has no distributed
                     refiner (surfaced HERE, in the dry run, instead of a
                     mid-run warning after an hour of calibration);
* ``skip``         — the rule leaves the site dense.

``PrunePlan.describe()`` renders the whole thing as a table — the dry-run
view ``launch/prune.py --plan-only`` and ``launch/prune_dryrun.py`` print.
``params`` may be a ``jax.eval_shape`` tree: planning reads shapes only.
"""
from __future__ import annotations

import dataclasses
import warnings

from jax.sharding import Mesh

from repro.core import masks as masks_lib

from . import engine as engine_lib
from . import recipe as recipe_lib
from . import sites as sites_lib
from . import stats as stats_lib


@dataclasses.dataclass(frozen=True)
class PlannedGroup:
    """One site group with its resolved rule and cost estimate."""

    spec: sites_lib.SiteSpec
    rule: recipe_lib.ResolvedRule
    engine_path: str             # batched | rows-sharded | gram-sharded |
                                 # single-device | skip

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def skip(self) -> bool:
        return self.rule.skip

    @property
    def weight_bytes(self) -> int:
        return 0 if self.skip else self.spec.weight_bytes

    @property
    def gram_bytes(self) -> int:
        return 0 if self.skip else self.spec.gram_bytes


def _engine_path(spec: sites_lib.SiteSpec, rule: recipe_lib.ResolvedRule,
                 mesh: Mesh | None, gram_budget_bytes: int) -> str:
    if rule.skip:
        return "skip"
    if mesh is None:
        return "batched"
    if rule.method != "sparseswaps":
        return "single-device"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # execution owns the warning
        regime = engine_lib._sharded_regime(
            rule.pattern, spec.d_in, mesh, gram_budget_bytes)
    return {"rows": "rows-sharded", "gram": "gram-sharded"}[regime]


@dataclasses.dataclass(frozen=True)
class PrunePlan:
    """The resolved, costed execution order ``PruneExecutor`` runs."""

    groups: tuple[PlannedGroup, ...]
    recipe: recipe_lib.PruneRecipe
    mesh: Mesh | None = None
    gram_budget_bytes: int = engine_lib.DEFAULT_GRAM_BUDGET
    swap_method: str = "auto"
    chunk: int = 512
    row_block: int | None = None
    compact_every: int | None = None   # active-row compaction period
    cfg: object = None           # ArchConfig; None only for legacy pickles

    @property
    def active_groups(self) -> tuple[PlannedGroup, ...]:
        return tuple(g for g in self.groups if not g.skip)

    @property
    def recover(self):
        """The recipe's attached RecoverSpec (None = no recovery pass)."""
        return getattr(self.recipe, "recover", None)

    def total_weight_bytes(self) -> int:
        return sum(g.weight_bytes for g in self.groups)

    def total_gram_bytes(self) -> int:
        return sum(g.gram_bytes for g in self.groups)

    def single_device_groups(self) -> list[str]:
        """Groups that asked for the mesh but will refine single-device."""
        return [g.name for g in self.groups
                if g.engine_path == "single-device"]

    def base_context(self) -> engine_lib.RefineContext:
        """Run-wide knobs; the executor layers rule overrides per group."""
        return engine_lib.RefineContext(
            warmstart=self.recipe.warmstart, t_max=self.recipe.t_max,
            eps=self.recipe.eps, swap_method=self.swap_method,
            chunk=self.chunk, row_block=self.row_block, mesh=self.mesh,
            gram_budget_bytes=self.gram_budget_bytes,
            k_swaps=self.recipe.k_swaps, compact_every=self.compact_every)

    def group_context(self, g: PlannedGroup) -> engine_lib.RefineContext:
        return self.base_context().with_overrides(
            warmstart=g.rule.warmstart, t_max=g.rule.t_max, eps=g.rule.eps,
            k_swaps=g.rule.k_swaps)

    # -- calibration costing ------------------------------------------------

    def calib_spec(self, *, minimal: bool = True,
                   kernel: str = "auto") -> stats_lib.CalibSpec:
        """The recipe-aware ``CalibSpec`` this plan needs (see stats)."""
        return stats_lib.CalibSpec.from_plan(self.cfg, self,
                                             minimal=minimal, kernel=kernel)

    def calib_costs(self, *, minimal: bool = True) -> list[tuple]:
        """(TapSpec, level) per calibration tap under the recipe."""
        spec = self.calib_spec(minimal=minimal)
        taps = sites_lib.tap_specs(self.cfg, [g.spec for g in self.groups])
        return [(t, spec.level(t.name)) for t in taps]

    def total_calib_bytes(self, *, minimal: bool = True) -> int:
        """Accumulator footprint during calibration (fp32, unsharded)."""
        return sum(t.bytes_at(lvl)
                   for t, lvl in self.calib_costs(minimal=minimal))

    def _calib_device_bytes(self, tap: sites_lib.TapSpec, level: str) -> int:
        """Per-device accumulator bytes, derived from the SAME sharding
        rule the accumulator actually uses (``dist.specs.calib_pspecs``
        over a shape stand-in of this tap's leaves) — data axes replicate,
        Gram leaves split over "model" when the rule shards them."""
        if level == "none":
            return 0
        import math

        import jax as _jax

        from repro.dist import specs as specs_lib

        n, d = tap.n, tap.d_in
        leaves = {"s": _jax.ShapeDtypeStruct((n, d), "float32"),
                  "n": _jax.ShapeDtypeStruct((n,), "float32")}
        leaves["g" if level == "gram" else "d"] = _jax.ShapeDtypeStruct(
            (n, d, d) if level == "gram" else (n, d), "float32")
        if self.mesh is None:
            pspecs = {k: None for k in leaves}
        else:
            pspecs = specs_lib.calib_pspecs(leaves, self.mesh)
        total = 0
        for k, leaf in leaves.items():
            shards = 1
            spec = pspecs[k]
            for axes in (spec or ()):
                if axes is None:
                    continue
                for a in ((axes,) if isinstance(axes, str) else axes):
                    shards *= self.mesh.shape[a]
            total += 4 * math.prod(leaf.shape) // shards
        return total

    def calib_bytes_per_device(self, *, minimal: bool = True) -> int:
        return sum(self._calib_device_bytes(t, lvl)
                   for t, lvl in self.calib_costs(minimal=minimal))

    def describe(self) -> str:
        """The dry-run table: every group, its treatment, its cost."""
        hdr = (f"{'site':30s} {'n':>4s} {'d_out x d_in':>14s} "
               f"{'pattern':>8s} {'method':>11s} {'warm':>9s} {'t_max':>5s} "
               f"{'k':>4s} {'path':>13s} {'W MiB':>8s} {'G MiB':>8s}")
        lines = [hdr, "-" * len(hdr)]
        for g in self.groups:
            s, r = g.spec, g.rule
            if g.skip:
                lines.append(
                    f"{s.name:30s} {s.n_instances:4d} "
                    f"{f'{s.d_out} x {s.d_in}':>14s} {'-':>8s} {'skip':>11s} "
                    f"{'-':>9s} {'-':>5s} {'-':>4s} {'skip':>13s} {'-':>8s} "
                    f"{'-':>8s}")
                continue
            k_s = "auto" if r.k_swaps is None else str(r.k_swaps)
            lines.append(
                f"{s.name:30s} {s.n_instances:4d} "
                f"{f'{s.d_out} x {s.d_in}':>14s} {r.pattern_str:>8s} "
                f"{r.method:>11s} {r.warmstart:>9s} {r.t_max:5d} "
                f"{k_s:>4s} {g.engine_path:>13s} {g.weight_bytes/2**20:8.1f} "
                f"{g.gram_bytes/2**20:8.1f}")
        lines.append("-" * len(hdr))
        n_active = len(self.active_groups)
        mesh_s = ("none" if self.mesh is None else
                  f"{'x'.join(str(d) for d in self.mesh.devices.shape)} "
                  f"({self.mesh.size} devices)")
        lines.append(
            f"{n_active}/{len(self.groups)} groups to refine | mesh: {mesh_s}"
            f" | totals: W {self.total_weight_bytes()/2**20:.1f} MiB, "
            f"G {self.total_gram_bytes()/2**20:.1f} MiB "
            f"(budget {self.gram_budget_bytes/2**20:.0f} MiB/device)")
        single = self.single_device_groups()
        if single:
            lines.append(
                f"NOTE: {len(single)} group(s) refine single-device despite "
                f"mesh= (no distributed refiner for their method): "
                + ", ".join(single))
        if self.cfg is not None:
            lines.append("")
            lines.extend(self._describe_calibration())
        if self.recover is not None:
            lines.append("")
            lines.extend(self._describe_recovery())
        return "\n".join(lines)

    def _describe_recovery(self) -> list[str]:
        """The post-prune recovery block: what retrains, for how long."""
        rec = self.recover
        warm = max(1, int(rec.warmup_frac * rec.steps))
        return [
            f"recovery (PERP): {rec.describe()}",
            f"  schedule: {warm}-step warmup -> cosine to "
            f"{rec.min_lr_frac:g}x lr | wd {rec.weight_decay:g} | "
            f"ckpt key {rec.fingerprint()} (under <ckpt_dir>/recover)"]

    def _describe_calibration(self) -> list[str]:
        """The calibration cost block: per-tap level + accumulator bytes.

        The table shows the *minimal* (recipe-aware) levels; the totals
        line also quotes the skip-aware full-Gram footprint — the
        executor / launcher default — so the operator sizes memory off
        whichever mode the run actually uses.
        """
        hdr = (f"{'calibration tap':30s} {'level':>8s} {'n x d':>12s} "
               f"{'MiB':>8s} {'MiB/dev':>8s}")
        lines = [hdr, "-" * len(hdr)]
        for tap, lvl in self.calib_costs(minimal=True):
            name = ".".join(tap.path)
            lines.append(
                f"{name:30s} {lvl:>8s} {f'{tap.n} x {tap.d_in}':>12s} "
                f"{tap.bytes_at(lvl)/2**20:8.2f} "
                f"{self._calib_device_bytes(tap, lvl)/2**20:8.2f}")
        lines.append("-" * len(hdr))
        minimal = self.total_calib_bytes(minimal=True)
        skip_full = self.total_calib_bytes(minimal=False)
        legacy = sum(t.bytes_at("gram") for t, _ in self.calib_costs())
        lines.append(
            f"calibration state: {skip_full/2**20:.2f} MiB skip-aware full "
            f"(executor default) | {minimal/2**20:.2f} MiB minimal "
            f"({self.calib_bytes_per_device(minimal=True)/2**20:.2f} "
            f"MiB/device) | {legacy/2**20:.2f} MiB legacy every-tap")
        return lines


def plan_pruning(api, params, recipe: recipe_lib.PruneRecipe, *,
                 mesh: Mesh | None = None,
                 gram_budget_bytes: int = engine_lib.DEFAULT_GRAM_BUDGET,
                 swap_method: str = "auto", chunk: int = 512,
                 row_block: int | None = None,
                 compact_every: int | None = None) -> PrunePlan:
    """Resolve ``recipe`` against the model's sites into a ``PrunePlan``.

    Pure shape arithmetic: ``params`` may be the ``jax.eval_shape`` tree of
    ``api.init`` and no calibration is required — the plan (and its
    ``describe()`` table) exists before any FLOP is spent.
    """
    specs = sites_lib.site_specs(api.cfg, params)
    recipe.validate(specs)
    groups = []
    for spec in specs:
        rule = recipe.resolve(spec.name, tuple(spec.labels()))
        groups.append(PlannedGroup(
            spec=spec, rule=rule,
            engine_path=_engine_path(spec, rule, mesh, gram_budget_bytes)))
    return PrunePlan(groups=tuple(groups), recipe=recipe, mesh=mesh,
                     gram_budget_bytes=gram_budget_bytes,
                     swap_method=swap_method, chunk=chunk,
                     row_block=row_block, compact_every=compact_every,
                     cfg=api.cfg)
