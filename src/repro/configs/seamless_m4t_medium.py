"""seamless-m4t-medium [audio] — encoder-decoder, multimodal backbone.

12L d_model=1024 16H (kv=16, MHA) d_ff=4096 vocab=256206, enc-dec
[arXiv:2308.11596; hf]

The audio frontend is a STUB per the shape spec: batch["src"] carries
precomputed frame embeddings (B, n_src_frames, d_model). 12 encoder +
12 decoder layers.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    mlp="plain",
    act="relu",
    n_src_frames=1024,
)

TINY = CONFIG.replace(
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, n_src_frames=16, dtype="float32",
)
