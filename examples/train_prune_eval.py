"""End-to-end driver: train -> prune (4 methods) -> evaluate.

    PYTHONPATH=src python examples/train_prune_eval.py [--steps 400]

Trains a ~1M-param llama-family model for a few hundred steps on the
synthetic Zipf-Markov corpus (checkpointed + restartable), calibrates,
prunes to 60% with magnitude / Wanda / Wanda+DSnoT / Wanda+SparseSwaps,
and compares perplexity + accuracy — the paper's Tables 1/2 workflow.
"""
import argparse

import repro.configs as configs
from repro import pruning
from repro.core import masks as masks_lib
from repro.launch.train import train
import repro.models as models


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--arch", default="llama31-8b")
    args = ap.parse_args()

    # scale the test config up a bit so pruning has signal
    tiny = configs.get_tiny(args.arch)
    cfg = tiny.replace(d_model=128, d_ff=384, n_layers=4, n_heads=4,
                       n_kv_heads=2, d_head=32, vocab_size=512,
                       dtype="float32")
    configs.TINY[configs.get(args.arch).name] = cfg

    print(f"1) training {args.arch} ({cfg.n_params()/1e6:.1f}M params) "
          f"for {args.steps} steps ...")
    out = train(args.arch, tiny=True, n_steps=args.steps, batch=16, seq=128,
                lr=2e-3, ckpt_dir="/tmp/repro_example_ckpt", ckpt_every=200,
                log_every=100)
    params = out["state"].params
    api = models.build(cfg)

    print("2) calibrating (one dense pass, streaming Gram accumulation) ...")
    batches = list(pruning.calibration_batches(cfg, n_samples=32,
                                               seq_len=128, batch_size=8))
    taps = pruning.accumulate(api, params, batches)

    print("3) pruning to 60% per-row sparsity ...")
    pat = masks_lib.PerRow(0.6)
    dense = pruning.evaluate(api, params, n_batches=4, batch=16, seq=128)
    print(f"   {'dense':24s} ppl {dense['perplexity']:8.2f}  "
          f"acc {100*dense['accuracy']:5.2f}%")
    for warm, method, label in (
            ("magnitude", "none", "magnitude"),
            ("wanda", "none", "wanda"),
            ("wanda", "dsnot", "wanda+DSnoT"),
            ("wanda", "sparseswaps", "wanda+SparseSwaps")):
        rep = pruning.prune_model(api, params, None, pat, method=method,
                                  warmstart=warm, t_max=50, taps=taps)
        ev = pruning.evaluate(api, params, masks=rep.masks, n_batches=4,
                              batch=16, seq=128)
        extra = (f"  err-red {100*rep.mean_error_reduction():5.1f}%"
                 if method != "none" else "")
        print(f"   {label:24s} ppl {ev['perplexity']:8.2f}  "
              f"acc {100*ev['accuracy']:5.2f}%{extra}")


if __name__ == "__main__":
    main()
