"""Batched sparse serving engine: pack once, serve from packed weights.

The serving counterpart of the pruning pipeline. ``ServeEngine`` takes a
model + a mask source (an in-memory tree, a ``PruneReport``, or any
pruning-run checkpoint directory — executor group checkpoints included)
and serves batched prefill + greedy decode in one of four weight
formats:

* ``dense``    — the unpruned baseline;
* ``masked``   — dense weights multiplied by 0/1 masks every matmul (the
  pre-packing reference path; arithmetic-faithful, zero bytes saved);
* ``nm24``     — 2:4/N:M index-packed values + uint8 metadata through
  ``kernels.spmm.spmm_nm24``;
* ``gathered`` — per-row kept-column gather through ``spmm_gather``.

Packing happens ONCE at construction (``core.packed.pack_tree``); the
packed leaves are ordinary pytree nodes, so the models' scan-over-layers
and ``dist.specs`` mesh sharding consume them unchanged — on a mesh the
packed values/idx shard exactly like the dense weight they replace.
Kernel selection mirrors the rest of the repo: ``"auto"`` is Pallas on
TPU and the take-along-columns jnp path elsewhere (the Pallas kernels
run under interpret off-TPU when forced).

``bench_rows`` emits the ``BENCH_serve.json`` rows the launcher writes:
separate prefill and decode rows per format (dense vs masked-dense vs
packed), each tagged with the kernel the trace actually lowered
(``kernel_used``) so jnp/VMEM fallbacks show up in the perf trajectory
instead of hiding inside an aggregate tok/s.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import packed as packed_lib
from repro.dist import specs as specs_lib
from repro.kernels import spmm
from repro.models import ModelApi, common
from repro.serve import sampling as sampling_lib

FORMATS = ("dense", "masked", "nm24", "gathered")


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (shape bucketing for jit stability)."""
    return 1 << max(int(n) - 1, 0).bit_length()


@dataclasses.dataclass
class ServeResult:
    """One timed generate() call."""

    tokens: jnp.ndarray        # (B, n_new) int32
    prefill_s: float
    decode_s: float
    n_new: int
    batch: int

    @property
    def tok_s(self) -> float:
        """Decode throughput (the serving steady state).

        With a single generated token there are zero decode steps, so
        fall back to end-to-end throughput instead of dividing the one
        prefill-produced token by an empty loop's microseconds.
        """
        steps = self.n_new - 1
        if steps <= 0:
            return self.batch * self.n_new / max(
                self.prefill_s + self.decode_s, 1e-9)
        return self.batch * steps / max(self.decode_s, 1e-9)


class ServeEngine:
    """Pack once at startup, then serve batched prefill/decode.

    Args:
        api/params: the model to serve (dense weights).
        masks: mask source for the sparse formats — a masks pytree, a
            ``PruneReport``, or a checkpoint directory (executor
            ``groups/``, a masks-tree checkpoint, or a launcher
            ``--out-dir`` root; see ``core.packed.load_mask_tree``).
            Required for ``masked``/``nm24``/``gathered``.
        fmt: one of ``FORMATS``.
        kernel: spmm kernel for packed formats ("auto"/"pallas"/"jnp").
        mesh: optional ``jax.sharding.Mesh`` — weights (packed or not)
            are placed with ``dist.specs.param_pspecs``-style sharding
            and the model's logical-axis rules are activated around
            every call.
    """

    def __init__(self, api: ModelApi, params: dict, *, masks=None,
                 fmt: str = "masked", kernel: str = "auto", mesh=None):
        if fmt not in FORMATS:
            raise ValueError(f"unknown serve format {fmt!r} "
                             f"(want one of {FORMATS})")
        self.api = api
        self.cfg = api.cfg
        self.fmt = fmt
        self.kernel = kernel
        self.mesh = mesh
        if fmt == "dense":
            masks = None           # baseline: original weights, no masks
        else:
            masks, params = self._resolve_masks(params, masks)
            if masks is None:
                raise ValueError(f"format {fmt!r} needs masks "
                                 "(tree, PruneReport, or checkpoint dir)")

        t0 = time.time()
        if fmt in ("nm24", "gathered"):
            self.params = packed_lib.pack_tree(self.cfg, params, masks, fmt)
            self.masks = None
        else:
            self.params = params
            self.masks = masks if fmt == "masked" else None
        self.pack_s = time.time() - t0
        self._policy = common.PackedMatmulPolicy(kernel)
        self._steps = None              # (prefill, decode) jits, built once
        self._scans: dict = {}          # (n_steps, want_logits, sampled) -> jit
        self._fns: dict = {}            # scheduler-facing compiled fns
        # per-phase kernel actually lowered at trace time ("dense" for the
        # unpacked formats, else e.g. "jnp" / "pallas" / "jnp(vmem)")
        self.kernel_used: dict = {}
        # fault-injection seam: called as hook(phase) inside the timed
        # dispatch region of every scheduler-facing entry point, so an
        # injected slow step lands in the measured lane time exactly
        # like a real straggler (serve.faultinject)
        self.dispatch_hook = None

        if mesh is not None:
            pspecs = specs_lib.param_pspecs(self.cfg, self.params, mesh)
            self.params = jax.device_put(
                self.params, specs_lib.named(mesh, pspecs))
            if self.masks is not None:
                mspecs = specs_lib.param_pspecs(self.cfg, self.masks, mesh)
                self.masks = jax.device_put(
                    self.masks, specs_lib.named(mesh, mspecs))

    def _resolve_masks(self, params, masks):
        """-> (masks tree | None, params) — a checkpoint source may also
        carry updated weights (sparsegpt), a report always does."""
        if masks is None or isinstance(masks, dict):
            return masks, params
        if isinstance(masks, (str, Path)):
            return packed_lib.load_masks_and_weights(self.cfg, params, masks)
        if hasattr(masks, "masks"):           # PruneReport
            if getattr(masks, "updated_params", None) is not None:
                params = masks.updated_params
            return masks.masks, params
        raise TypeError(f"cannot interpret masks source {type(masks)!r}")

    @classmethod
    def from_executor_ckpt(cls, api: ModelApi, params: dict,
                           ckpt_dir: str | Path, **kw) -> "ServeEngine":
        """Serve the masks a (possibly still-running) executor published."""
        return cls(api, params, masks=ckpt_dir, **kw)

    # -- accounting ---------------------------------------------------------

    def weight_bytes(self) -> int:
        """Resident weight bytes this engine serves from (masks included:
        the masked-dense path genuinely keeps them in memory)."""
        total = packed_lib.packed_bytes(self.params)
        if self.masks is not None:
            total += sum(int(l.nbytes) for l in jax.tree.leaves(self.masks))
        return total

    # -- serving ------------------------------------------------------------

    def _ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.launch import mesh as mesh_lib
        return mesh_lib.activate(self.mesh, self.cfg)

    def _serve_steps(self):
        if self._steps is None:
            from repro.train import steps as steps_lib
            self._steps = steps_lib.make_serve_steps(self.api,
                                                     masks=self.masks)
        return self._steps

    def _decode_scan(self, n_steps: int, want_logits: bool,
                     sampled: bool = False):
        """One jitted ``lax.scan`` over the whole decode loop.

        A Python decode loop pays one dispatch (pytree flatten + device
        round-trip) per token; at serving batch sizes that fixed cost
        swamps the per-step matmul work and buries the packed-kernel
        advantage in noise. Scanning the step in-graph makes decode a
        single dispatch for all ``n_steps`` tokens — what the timed
        phase should measure. Compiled once per (n_steps, want_logits,
        sampled) and cached on the engine like the prefill/decode jits;
        the greedy graph stays pure argmax (no sort in the timed phase),
        the sampled graph takes the per-row knobs as traced (B,) arrays
        so changing temperature/seed never recompiles.
        """
        key = (n_steps, want_logits, sampled)
        if key not in self._scans:
            _, decode = self._serve_steps()

            def run(params, tok0, cache, samp):
                def step(carry, _):
                    tok, cache = carry
                    logits, cache = decode(params, tok[:, None], cache)
                    if sampled:
                        # post-step cache.t IS the absolute position of
                        # the token being sampled (the PRNG key index)
                        nxt = sampling_lib.sample_tokens(
                            logits[:, -1], samp["temp"], samp["top_p"],
                            samp["top_k"], samp["seed"], cache.t)
                    else:
                        nxt = jnp.argmax(logits[:, -1],
                                         axis=-1).astype(jnp.int32)
                    out = (nxt, logits[:, -1].astype(jnp.float32)) \
                        if want_logits else nxt
                    return (nxt, cache), out

                (_, cache), ys = jax.lax.scan(step, (tok0, cache), None,
                                              length=n_steps)
                return ys

            self._scans[key] = jax.jit(run)
        return self._scans[key]

    def _greedy_loop(self, prompt: dict, n_new: int, *,
                     want_logits: bool = False, sampling=None):
        """The one prefill → sample → decode loop both surfaces consume.

        The active ``MatmulPolicy`` is installed around the traced calls,
        so packed leaves lower through the spmm kernels inside the same
        jitted prefill/decode programs the dense path uses. Returns
        (tokens (B, n_new), last-step logits (n_new, B, V) fp32 or None,
        prefill_s, decode_s). The logits trace is only accumulated when
        asked — the casts/stack must not sit inside timed decode.

        The cache is sized to the pow2 bucket of ``S + n_new`` (extra
        slots carry pos = -1 and are masked out of every score), so the
        decode scan compiles once per (bucket, n_new) instead of once
        per exact (prompt_len, n_new) pair.

        ``sampling`` is None for greedy, else a ``SamplingParams`` (or
        one per batch row); the token at absolute position p draws from
        ``fold_in(key(seed), p)`` — the same key the continuous
        scheduler uses, so a request replays identically on both paths.
        """
        B, S = prompt["tokens"].shape
        samp = None
        if sampling is not None:
            per_row = sampling if isinstance(sampling, (list, tuple)) \
                else [sampling] * B
            samp = sampling_lib.params_arrays(list(per_row))
        with self._ctx(), common.use_matmul_policy(self._policy):
            if self.mesh is not None:
                prompt = jax.device_put(prompt, specs_lib.named(
                    self.mesh, specs_lib.batch_pspecs(self.cfg, prompt,
                                                      self.mesh)))
            cache = self.api.init_cache(self.params, B,
                                        next_pow2(S + n_new))
            prefill, _ = self._serve_steps()
            t0 = time.time()
            # dispatch decisions are trace-time constants, so the records
            # only materialize on the cold (tracing) call of each jit —
            # warm calls leave the log empty and keep the noted value.
            with spmm.record_dispatch() as rec_p:
                logits0, cache = prefill(self.params, prompt, cache)
            if samp is None:
                tok0 = jnp.argmax(logits0[:, -1], axis=-1).astype(jnp.int32)
            else:
                tok0 = sampling_lib.sample_tokens(
                    logits0[:, -1], samp["temp"], samp["top_p"],
                    samp["top_k"], samp["seed"], jnp.int32(S))
            jax.block_until_ready(tok0)
            t1 = time.time()
            rec_d: list = []
            trace = None
            if n_new > 1:
                # the whole decode loop is ONE scanned dispatch — the
                # timed phase measures graph cost, not n_new-1 python
                # round-trips (see _decode_scan)
                run = self._decode_scan(n_new - 1, want_logits,
                                        samp is not None)
                with spmm.record_dispatch() as rec_d:
                    ys = run(self.params, tok0, cache, samp)
                toks, logit_steps = ys if want_logits else (ys, None)
                out = jnp.concatenate([tok0[:, None], toks.T], axis=1)
            else:
                out, logit_steps = tok0[:, None], None
            jax.block_until_ready(out)
            t2 = time.time()
        self._note_kernels("prefill", rec_p)
        self._note_kernels("decode", rec_d)
        if want_logits:
            first = logits0[:, -1].astype(jnp.float32)[None]
            trace = first if logit_steps is None else \
                jnp.concatenate([first, logit_steps], axis=0)
        return out, trace, t1 - t0, t2 - t1

    def _note_kernels(self, phase: str, rec: list) -> None:
        if rec:
            self.kernel_used[phase] = _kernel_summary(rec)
        elif phase not in self.kernel_used:
            # no spmm dispatches traced: dense/masked serve plain matmuls
            self.kernel_used[phase] = "dense"

    def generate(self, prompt: dict, n_new: int, *,
                 sampling=None) -> ServeResult:
        """Batched prefill + ``n_new`` decode steps, timed.

        ``sampling=None`` decodes greedily (the historical behaviour);
        a ``SamplingParams`` — or a list of one per batch row — samples
        with per-request seeds (see ``serve.sampling``).
        """
        tokens, _, prefill_s, decode_s = self._greedy_loop(
            prompt, n_new, sampling=sampling)
        return ServeResult(tokens=tokens, prefill_s=prefill_s,
                           decode_s=decode_s, n_new=n_new,
                           batch=tokens.shape[0])

    def logits_trace(self, prompt: dict, n_new: int) -> jnp.ndarray:
        """(n_new, B, vocab) greedy logits — the parity-test surface."""
        return self._greedy_loop(prompt, n_new, want_logits=True)[1]

    # -- continuous-batching step fns (consumed by serve.scheduler) ---------

    @property
    def supports_continuous(self) -> bool:
        """Continuous batching needs the plain decoder-only KV layout:
        per-token pages and a per-row decode clock. Recurrent families
        (rwkv, zamba) carry state, not per-token KV; cross-attn caches
        (VLM) and encoder-decoder models add a second, unpaged cache."""
        from repro.models import transformer
        return (self.api.module is transformer
                and not getattr(self.cfg, "cross_attn_every", 0))

    def _require_continuous(self):
        if not self.supports_continuous:
            raise NotImplementedError(
                f"continuous batching supports plain decoder-only "
                f"transformers; {self.cfg.name!r} is not one")

    def prefill_session(self, tokens: jnp.ndarray, n_valid: int, samp: dict):
        """Prefill ONE session from a right-padded prompt row.

        ``tokens`` is (1, S_bucket) int32 with the real prompt in the
        first ``n_valid`` positions; ``samp`` holds (1,) sampling arrays
        (``sampling.params_arrays``). Returns ``(tok0 (1,) int32,
        k (L, S_bucket, kvH, dh), v)`` — the first generated token
        (sampled at PRNG position ``n_valid``) and the dense cache row to
        scatter into pages. Compiled once per S_bucket: ``n_valid`` is a
        traced scalar, so every prompt length in a bucket shares the jit.
        """
        self._require_continuous()
        s_bucket = tokens.shape[1]
        key = ("prefill_session", s_bucket)
        if key not in self._fns:
            def fn(params, tokens, n_valid, samp):
                cache = self.api.init_cache(params, 1, s_bucket)
                logits, cache = self.api.prefill(
                    params, {"tokens": tokens, "n_valid": n_valid}, cache,
                    masks=self.masks)
                tok0 = sampling_lib.sample_tokens(
                    logits[:, -1], samp["temp"], samp["top_p"],
                    samp["top_k"], samp["seed"], n_valid)
                kv = cache.kv
                return tok0, kv.k[:, 0], kv.v[:, 0]

            self._fns[key] = jax.jit(fn)
        with self._ctx(), common.use_matmul_policy(self._policy):
            if self.dispatch_hook is not None:
                self.dispatch_hook("prefill")
            with spmm.record_dispatch() as rec:
                out = self._fns[key](self.params, tokens,
                                     jnp.int32(n_valid), samp)
            jax.block_until_ready(out[0])
        self._note_kernels("prefill", rec)
        return out

    def prefill_chunk(self, tokens: jnp.ndarray, offset: int, n_valid: int,
                      cache, samp: dict):
        """One fixed-width window of a chunked prefill (B=1).

        ``tokens`` is (1, W) int32 — the prompt slice at absolute
        positions ``[offset, offset + W)`` (the final window right-pads
        past ``n_valid``); ``cache`` is the session's continuation cache
        (B=1, capacity = the prompt's pow2 bucket) holding the previous
        windows' KV. Returns ``(tok0 (1,) int32, cache')`` — the token
        sampled from the last real position seen so far (only the FINAL
        window's ``tok0`` is the request's first token; earlier windows'
        are a one-row lm_head by-product the scheduler ignores).

        Compiled once per (W, capacity): ``offset`` and ``n_valid`` are
        traced scalars, so every window of every prompt in a bucket
        shares the jit, and the cache buffers are donated between
        windows. Driving ⌈S/W⌉ windows is bitwise-identical to one
        ``prefill_session`` call over the same bucket — same per-row
        reduction lengths, masked slots contribute exact zeros (see
        ``models.attention.window_attention``).
        """
        self._require_continuous()
        if self.api.prefill_window is None:
            raise NotImplementedError(
                f"{self.cfg.name!r} has no windowed-prefill continuation")
        w = tokens.shape[1]
        capacity = cache.kv.k.shape[2]
        key = ("prefill_chunk", w, capacity)
        if key not in self._fns:
            def fn(params, tokens, offset, n_valid, cache, samp):
                logits, cache = self.api.prefill_window(
                    params, {"tokens": tokens, "offset": offset,
                             "n_valid": n_valid}, cache, masks=self.masks)
                tok0 = sampling_lib.sample_tokens(
                    logits[:, -1], samp["temp"], samp["top_p"],
                    samp["top_k"], samp["seed"], n_valid)
                return tok0, cache

            self._fns[key] = jax.jit(fn, donate_argnums=4)
        with self._ctx(), common.use_matmul_policy(self._policy):
            if self.dispatch_hook is not None:
                self.dispatch_hook("prefill")
            with spmm.record_dispatch() as rec:
                tok0, cache = self._fns[key](
                    self.params, tokens, jnp.int32(offset),
                    jnp.int32(n_valid), cache, samp)
            jax.block_until_ready(tok0)
        self._note_kernels("prefill", rec)
        return tok0, cache

    def decode_chunk(self, tok: jnp.ndarray, cache, active: jnp.ndarray,
                     samp: dict, *, n_steps: int, bucket: int):
        """Run ``n_steps`` decode steps on rows ``[:bucket]`` of a
        full-width working cache; rows beyond the bucket pass through
        untouched.

        ``tok`` (B,) holds each slot's last token, ``active`` (B,) bool
        masks live slots — inactive rows hold their token and FREEZE
        their clock ``t`` (their in-step KV write lands in the slack
        region past their session length, where the contiguity contract
        already says garbage lives, so nothing real is harmed). Returns
        ``(toks (n_steps, bucket), cache')``. Compiled once per
        (n_steps, bucket) — the slice/write-back lives in-graph so the
        whole chunk stays one dispatch, and the cache buffers are
        donated.
        """
        self._require_continuous()
        from repro.models import attention as attn
        from repro.models.transformer import DecodeCache
        key = ("chunk", n_steps, bucket)
        if key not in self._fns:
            def fn(params, tok, cache, active, samp):
                kv = cache.kv
                sub = DecodeCache(
                    kv=attn.KVCache(kv.k[:, :bucket], kv.v[:, :bucket],
                                    kv.pos[:, :bucket], kv.rolling),
                    cross_kv=None, t=cache.t[:bucket])
                act = active[:bucket]

                def step(carry, _):
                    tk, c = carry
                    logits, c2 = self.api.decode_step(
                        params, tk[:, None], c, masks=self.masks)
                    nxt = sampling_lib.sample_tokens(
                        logits[:, -1], samp["temp"][:bucket],
                        samp["top_p"][:bucket], samp["top_k"][:bucket],
                        samp["seed"][:bucket], c2.t)
                    nxt = jnp.where(act, nxt, tk)
                    c2 = c2._replace(t=jnp.where(act, c2.t, c.t))
                    return (nxt, c2), nxt

                (_, sub), toks = jax.lax.scan(
                    step, (tok[:bucket], sub), None, length=n_steps)
                kv2 = sub.kv
                kv_out = attn.KVCache(
                    kv.k.at[:, :bucket].set(kv2.k),
                    kv.v.at[:, :bucket].set(kv2.v),
                    kv.pos.at[:, :bucket].set(kv2.pos), kv.rolling)
                return toks, DecodeCache(
                    kv=kv_out, cross_kv=None,
                    t=cache.t.at[:bucket].set(sub.t))

            self._fns[key] = jax.jit(fn, donate_argnums=2)
        with self._ctx(), common.use_matmul_policy(self._policy):
            if self.dispatch_hook is not None:
                self.dispatch_hook("decode")
            with spmm.record_dispatch() as rec:
                toks, cache = self._fns[key](self.params, tok, cache,
                                             active, samp)
            jax.block_until_ready(toks)
        self._note_kernels("decode", rec)
        return toks, cache

    def compiled_fn_keys(self) -> list:
        """Keys of the scheduler-facing compiled fns (jit-churn tests)."""
        return sorted(self._fns, key=repr)


def kernel_summary(rec: list) -> str:
    """Collapse trace-time dispatch records into one bench-row tag."""
    names = sorted({r["kernel"] for r in rec})
    tag = "+".join(names)
    if any(r["reason"] == "vmem" for r in rec):
        tag += "(vmem-fallback)"
    return tag


_kernel_summary = kernel_summary


def bench_rows(api: ModelApi, params: dict, masks, prompt: dict,
               n_new: int, *, formats=("dense", "masked", "nm24"),
               kernel: str = "auto", mesh=None, repeats: int = 3,
               masked_params: dict | None = None) -> list:
    """Dense vs masked-dense vs packed serving rows for BENCH_serve.json.

    Each format contributes TWO rows — ``phase == "prefill"`` and
    ``phase == "decode"`` — so the prefill gap is tracked directly
    instead of inferred from aggregate tok/s. Shared keys: ``variant``,
    ``kernel`` (requested), ``kernel_used`` (what the trace actually
    lowered, per phase — fallbacks are visible here), ``tok_s`` (best
    warm repeat), ``weight_bytes``, ``pack_s``. Prefill rows add
    ``prefill_s`` (best warm, tok_s = batch · prompt_len / prefill_s);
    decode rows add ``cold_tok_s`` (first call, pays compilation).
    ``masked_params`` are the weights the masks belong to when they
    differ from the dense baseline (sparsegpt updates); the dense row
    always serves ``params``.
    """
    B, S = prompt["tokens"].shape
    engines, cold = {}, {}
    for fmt in formats:
        p = params if fmt == "dense" or masked_params is None \
            else masked_params
        engines[fmt] = ServeEngine(api, p, masks=masks if fmt != "dense"
                                   else None, fmt=fmt, kernel=kernel,
                                   mesh=mesh)
        # compile (and record dispatch) up front
        cold[fmt] = engines[fmt].generate(prompt, n_new)
    # interleave the timed repeats round-robin across engines so clock
    # drift (turbo ramp, background load) biases no single variant —
    # serial per-variant timing systematically favors whichever runs
    # last on a warming machine
    warm: dict = {fmt: [] for fmt in formats}
    for _ in range(repeats):
        for fmt in formats:
            warm[fmt].append(engines[fmt].generate(prompt, n_new))
    rows = []
    for fmt in formats:
        eng = engines[fmt]
        results = [cold[fmt], *warm[fmt]]
        base = {
            "variant": fmt,
            "kernel": kernel if fmt in ("nm24", "gathered") else "dense",
            "weight_bytes": eng.weight_bytes(),
            "pack_s": eng.pack_s,
        }
        prefill_s = min(r.prefill_s for r in results[1:])
        rows.append({
            **base, "phase": "prefill",
            "kernel_used": eng.kernel_used.get("prefill", "dense"),
            "prefill_s": prefill_s,
            "tok_s": B * S / max(prefill_s, 1e-9),
        })
        rows.append({
            **base, "phase": "decode",
            "kernel_used": eng.kernel_used.get("decode", "dense"),
            "cold_tok_s": results[0].tok_s,
            "tok_s": max(r.tok_s for r in results[1:]),
        })
    return rows
