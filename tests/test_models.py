"""Per-architecture smoke tests (reduced same-family configs, CPU).

Every assigned arch: one forward/train step asserting output shapes and
no NaNs, one prefill+decode consistency check, and recurrence exactness
for the chunked SSM/WKV paths.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
import repro.models as models
from repro.optim import adamw
from repro.train import steps as steps_lib

ALL_ARCHS = configs.ASSIGNED + ["llama31-8b"]


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = configs.get_tiny(name)
            api = models.build(cfg)
            params = api.init(jax.random.key(0))
            cache[name] = (cfg, api, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(built, arch):
    cfg, api, params = built(arch)
    B, S = 2, 24
    batch = models.make_batch(cfg, B, S, jax.random.key(1))
    loss, aux = api.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    hidden, _, _ = api.forward(params, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_no_nans(built, arch):
    cfg, api, params = built(arch)
    state = steps_lib.TrainState(params=params, opt=adamw.init(params))
    step = steps_lib.make_train_step(api, adamw.AdamWConfig(lr=1e-3),
                                     donate=False)
    batch = models.make_batch(cfg, 2, 16, jax.random.key(2))
    state2, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    # params actually changed
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, state2.params)
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(built, arch):
    """Greedy decode over a teacher-forced prefix reproduces forward logits."""
    cfg, api, params = built(arch)
    if cfg.is_moe:
        # capacity depends on group length: forward at S may drop tokens
        # that a 1-token decode never drops (GShard semantics). Test with
        # drop-free capacity so the paths are comparable.
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
        api = models.build(cfg)
    B, S = 2, 12
    # one draw; the prefill prompt is its prefix (same token stream)
    ext = models.make_batch(cfg, B, S + 1, jax.random.key(3))
    batch = dict(ext)
    batch["tokens"] = ext["tokens"][:, :S]
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    hidden, _, _ = api.forward(params, batch)
    full_logits = api.module.lm_head(params, hidden, cfg)     # (B, S, V)

    cache = api.init_cache(params, B, S + 4)
    pre_logits, cache = api.prefill(params, batch, cache)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, -1], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-2, atol=2e-2)

    # decode the next token with teacher forcing: feed tokens[:, S] and
    # compare to the full forward at position S
    dec_logits, cache = api.decode_step(
        params, ext["tokens"][:, S:S + 1], cache)
    batch2 = dict(ext)
    batch2["labels"] = jnp.roll(batch2["tokens"], -1, 1)
    hidden2, _, _ = api.forward(params, batch2)
    want = api.module.lm_head(params, hidden2, cfg)[:, -1]
    np.testing.assert_allclose(np.asarray(dec_logits[:, -1], np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_param_counts_match_configs():
    """Full-size param counts are in the right ballpark for the labels."""
    expect = {"chatglm3-6b": 6e9, "granite-34b": 34e9, "minitron-4b": 4e9,
              "internlm2-20b": 20e9, "mixtral-8x7b": 47e9,
              "rwkv6-1.6b": 1.6e9, "llama31-8b": 8e9,
              "zamba2-7b": 7e9}
    for name, n in expect.items():
        got = configs.get(name).n_params()
        assert 0.55 * n < got < 1.7 * n, (name, got, n)


def test_moe_active_params_smaller():
    cfg = configs.get("mixtral-8x7b")
    assert cfg.n_active_params() < 0.45 * cfg.n_params()


def test_rwkv_chunked_matches_step():
    """Chunked WKV == exact per-token recurrence."""
    from repro.models import rwkv6
    rng = np.random.default_rng(0)
    B, S, H, dh = 2, 13, 2, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, S, H, dh)).astype(np.float32))
               for _ in range(3))
    logw = -jnp.asarray(rng.uniform(0.01, 2.0, size=(B, S, H, dh)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, dh)).astype(np.float32))
    o_chunk, s_chunk = rwkv6.wkv_chunked(r, k, v, logw, u, chunk=4)
    s = jnp.zeros((B, H, dh, dh))
    outs = []
    for t in range(S):
        o, s = rwkv6.wkv_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, s)
        outs.append(o)
    o_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_step),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s),
                               rtol=1e-3, atol=1e-3)


def test_mamba_chunked_matches_step():
    from repro.models import mamba2
    rng = np.random.default_rng(1)
    B, S, H, dh, ds = 2, 11, 2, 4, 6
    x = jnp.asarray(rng.normal(size=(B, S, H, dh)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, ds)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, ds)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.1, 1.0, size=(B, S, H)).astype(np.float32))
    A = -jnp.ones((H,))
    y_chunk, h_chunk = mamba2.ssd_chunked(x, Bm, Cm, dt, A, chunk=4)
    h = jnp.zeros((B, H, dh, ds))
    ys = []
    for t in range(S):
        y, h = mamba2.ssm_step(x[:, t], Bm[:, t], Cm[:, t], dt[:, t], A, h)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=1e-3, atol=1e-3)


def test_attention_chunked_matches_full():
    from repro.models import attention as attn
    import repro.configs as C
    cfg = C.get_tiny("llama31-8b").replace(attn_impl="full")
    api = models.build(cfg)
    params = api.init(jax.random.key(0))
    batch = models.make_batch(cfg, 2, 32, jax.random.key(1))
    h1, _, _ = api.forward(params, batch)
    cfg2 = cfg.replace(attn_impl="chunked", attn_q_chunk=8)
    api2 = models.build(cfg2)
    h2, _, _ = api2.forward(params, batch)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), rtol=2e-2,
                               atol=2e-2)
