"""minitron-4b [dense] — pruned nemotron: squared-ReLU MLP, huge vocab.

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
[arXiv:2407.14679; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    mlp="plain",
    act="relu2",           # nemotron squared relu
    rope_pct=0.5,          # nemotron partial rotary
)

TINY = CONFIG.replace(
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=128,
    vocab_size=512, dtype="float32",
)
