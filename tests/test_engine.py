"""Group-batched engine vs the per-instance reference + mask-tree
round-trips on the stacked families (MoE experts, hybrid shared blocks).

The multi-device ``prune_model(mesh=...)`` bit-identity test lives in
test_distributed.py (it needs its own XLA_FLAGS subprocess)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
import repro.models as models
from repro import pruning
from repro.core import masks as masks_lib


def _setup(arch, *, n_samples=2, seq_len=24, batch_size=2):
    cfg = configs.get_tiny(arch)
    api = models.build(cfg)
    params = api.init(jax.random.key(0))
    batches = list(pruning.calibration_batches(
        cfg, n_samples=n_samples, seq_len=seq_len, batch_size=batch_size))
    taps = pruning.accumulate(api, params, batches)
    return cfg, api, params, taps


def _get(tree, path):
    for k in path:
        tree = tree[k]
    return tree


MOE_HYBRID = ["mixtral-8x7b", "zamba2-7b"]


@pytest.mark.parametrize("arch", MOE_HYBRID)
def test_mask_tree_round_trip(arch):
    """enumerate_sites -> refine -> build_mask_tree lands every mask leaf at
    its param path with the stack dims restored (experts, shared blocks)."""
    cfg, api, params, taps = _setup(arch)
    groups = pruning.enumerate_sites(cfg, params, taps)
    pat = masks_lib.PerRow(0.5)
    rep = pruning.prune_model(api, params, None, pat, method="none",
                              taps=taps)
    for g in groups:
        leaf = _get(rep.masks, g.mask_path)
        w = _get(params, g.mask_path)
        assert leaf.shape == w.shape, (g.name, leaf.shape, w.shape)
        flat = np.asarray(leaf).reshape(-1, leaf.shape[-1])
        assert masks_lib.validate_mask(jnp.asarray(flat), pat), g.name
    batch = models.make_batch(cfg, 2, 16, jax.random.key(3))
    loss, _ = api.loss(params, batch, masks=rep.masks)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", MOE_HYBRID)
def test_gram_batch_matches_instances(arch):
    """The stacked GramBatch slices back to exactly the per-instance stats."""
    cfg, api, params, taps = _setup(arch)
    for g in pruning.enumerate_sites(cfg, params, taps):
        assert g.gram.G.shape[0] == g.n_instances
        assert g.gram.mean.shape == (g.n_instances, g.weights.shape[2])
        for i, inst in enumerate(g.grams):
            np.testing.assert_array_equal(np.asarray(inst.G),
                                          np.asarray(g.gram.G[i]))
            np.testing.assert_array_equal(np.asarray(inst.ex2),
                                          np.asarray(g.gram.ex2[i]))


@pytest.mark.parametrize("method", ["none", "sparseswaps", "dsnot",
                                    "sparsegpt"])
def test_batched_matches_reference(method):
    """Group-batched engine == per-instance loop, bit-identical masks."""
    cfg, api, params, taps = _setup("llama31-8b")
    pat = masks_lib.PerRow(0.6)
    kw = dict(method=method, warmstart="wanda", t_max=8, taps=taps)
    rep_b = pruning.prune_model(api, params, None, pat, **kw)
    rep_r = pruning.prune_model(api, params, None, pat,
                                engine_mode="reference", **kw)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        rep_b.masks, rep_r.masks)
    for sb, sr in zip(rep_b.sites, rep_r.sites):
        np.testing.assert_allclose(np.asarray(sb.loss_final),
                                   np.asarray(sr.loss_final),
                                   rtol=1e-5, atol=1e-5)
    if method == "sparsegpt":
        # masks are bit-identical; the OBS weight updates go through
        # inv+cholesky, whose batched LAPACK kernels differ at ~1e-5
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-3, atol=1e-4),
            rep_b.updated_params, rep_r.updated_params)


@pytest.mark.parametrize("arch", MOE_HYBRID)
def test_batched_matches_reference_stacked_families(arch):
    """Bit-identity holds across expert stacks and summed shared blocks."""
    cfg, api, params, taps = _setup(arch)
    pat = masks_lib.PerRow(0.5)
    kw = dict(method="sparseswaps", t_max=5, taps=taps)
    rep_b = pruning.prune_model(api, params, None, pat, **kw)
    rep_r = pruning.prune_model(api, params, None, pat,
                                engine_mode="reference", **kw)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        rep_b.masks, rep_r.masks)


def test_batched_matches_reference_nm():
    cfg, api, params, taps = _setup("llama31-8b")
    pat = masks_lib.NM(2, 4)
    kw = dict(method="sparseswaps", t_max=6, taps=taps)
    rep_b = pruning.prune_model(api, params, None, pat, **kw)
    rep_r = pruning.prune_model(api, params, None, pat,
                                engine_mode="reference", **kw)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        rep_b.masks, rep_r.masks)


def test_unknown_method_raises():
    cfg, api, params, taps = _setup("llama31-8b")
    with pytest.raises(ValueError, match="unknown method"):
        pruning.prune_model(api, params, None, masks_lib.PerRow(0.5),
                            method="nope", taps=taps)
