"""Pruning pipeline: recipe -> plan -> execute (calibrate/refine/apply).

``prune_model`` remains the one-call entry point (a single-rule recipe);
``PruneRecipe``/``plan_pruning``/``PruneExecutor`` expose the staged API
with per-site rules, dry-run cost tables and group-granular resume.
"""
from .calibrate import accumulate, calibration_batches, make_tap_step
from .engine import (GroupResult, RefineContext, refine_group,
                     refine_group_reference, register)
from .evaluate import evaluate, perplexity, top1_accuracy, val_batches
from .executor import (PruneCallback, PruneExecutor, PrintProgress)
from .pipeline import PruneReport, SiteReport, apply, prune_model
from .plan import PlannedGroup, PrunePlan, plan_pruning
from .recipe import PruneRecipe, ResolvedRule, SiteRule
from .recover import RecoverResult, RecoverSpec, recover
from .sites import (GramBatch, GramStats, SiteGroup, SiteSpec, TapSpec,
                    build_mask_tree, enumerate_sites, prunable_param_count,
                    site_specs, tap_specs)
from .stats import CalibSpec, CalibStats, accumulate_stats

__all__ = [
    "CalibSpec", "CalibStats", "GramBatch", "GramStats", "GroupResult",
    "PlannedGroup", "PrintProgress",
    "PruneCallback", "PruneExecutor", "PrunePlan", "PruneRecipe",
    "PruneReport", "RecoverResult", "RecoverSpec", "RefineContext",
    "ResolvedRule", "SiteGroup", "SiteReport",
    "SiteRule", "SiteSpec", "TapSpec", "accumulate", "accumulate_stats",
    "apply", "build_mask_tree",
    "calibration_batches", "enumerate_sites", "evaluate", "make_tap_step",
    "perplexity", "plan_pruning", "prunable_param_count", "prune_model",
    "recover", "refine_group", "refine_group_reference", "register",
    "site_specs", "tap_specs", "top1_accuracy", "val_batches",
]
