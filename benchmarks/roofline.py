"""Roofline table: reads the dry-run artifacts (results/dryrun/*) and
prints the per-(arch x shape x mesh) three-term roofline (DESIGN §7),
plus the analytic swap-search roofline — bytes moved and FLOPs per
ACCEPTED swap for the per-iteration argmin path vs the fused top-k
kernel (``kernels/swap_topk``) — plus the serving-kernel table for the
fused packed spmm (``kernels/spmm``): packed HBM bytes, slot-expansion
VPU ops, and MXU utilization per tile shape for nm24 vs gathered at
prefill and decode token counts. The headline metric is G HBM re-reads
per accepted swap: the argmin path streams the whole d_in² Gram once
per swap; the k-swap path streams it once per ~A accepted swaps (A =
accepts/pass) and pays O(R·d) column gathers per accept instead.

Run ``python -m repro.launch.dryrun`` first (or use the committed
artifacts) for the mesh tables; the swap-search table is closed-form.
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "results" / "dryrun"


def swap_search_rows(shapes=(4096, 14336, 24576), *, row_block=16, k=8,
                     accepts_per_pass=4.0):
    """Closed-form bytes/FLOPs per ACCEPTED swap, argmin vs fused top-k.

    One search pass over a row block of RB rows streams the whole Gram
    once from HBM (``d²·4`` bytes — the kernels revisit G tiles per row
    block) and spends ``≈3·RB·d²`` ΔL flops. The argmin path accepts at
    most ONE swap per row per pass; the fused top-k path accepts up to k
    (``accepts_per_pass`` ≈ A, the measured average on the bench
    config), so the same G stream is amortized over A× more swaps. Every
    accepted swap additionally gathers ~3 G columns (the commit's column
    re-search + the Eq. 6 rank-1 update): ``3·d·4`` bytes and ``~6·d``
    flops per swap — negligible next to the d²/RB search share at LLM
    widths. ``g_reads_per_swap`` (full-G HBM streams per accepted swap,
    per row block) is the headline: 1/RB vs 1/(A·RB).
    """
    rows = []
    a = min(accepts_per_pass, k)
    for d in shapes:
        g_bytes = 4 * d * d
        search_flops_row = 3 * d * d          # per row, per pass
        argmin = {
            "path": "argmin", "d_in": d, "row_block": row_block,
            "g_reads_per_swap": 1.0 / row_block,
            "hbm_bytes_per_swap": g_bytes / row_block + 2 * d * 4,
            "flops_per_swap": search_flops_row,
        }
        topk = {
            "path": f"topk(k={k})", "d_in": d, "row_block": row_block,
            "g_reads_per_swap": 1.0 / (a * row_block),
            "hbm_bytes_per_swap": g_bytes / (a * row_block) + 3 * d * 4,
            "flops_per_swap": search_flops_row / a + 6 * d,
        }
        for r in (argmin, topk):
            r["intensity_flop_per_byte"] = (r["flops_per_swap"]
                                            / r["hbm_bytes_per_swap"])
        rows.append((argmin, topk))
    return rows


def print_swap_search(rows=None, *, k=8, accepts_per_pass=4.0):
    if rows is None:
        rows = swap_search_rows(k=k, accepts_per_pass=accepts_per_pass)
    hdr = (f"{'d_in':>7s} {'RB':>4s} {'path':>12s} {'G-reads/swap':>13s} "
           f"{'HBM B/swap':>12s} {'FLOP/swap':>12s} {'FLOP/B':>8s}")
    print(f"\n=== swap-search roofline (fp32, A≈{accepts_per_pass:.0f} "
          f"accepts/pass measured on the bench config) ===")
    print(hdr)
    for argmin, topk in rows:
        for r in (argmin, topk):
            print(f"{r['d_in']:7d} {r['row_block']:4d} {r['path']:>12s} "
                  f"{r['g_reads_per_swap']:13.4f} "
                  f"{r['hbm_bytes_per_swap']:12.3e} "
                  f"{r['flops_per_swap']:12.3e} "
                  f"{r['intensity_flop_per_byte']:8.1f}")
        g_cut = (argmin["hbm_bytes_per_swap"] / topk["hbm_bytes_per_swap"])
        print(f"{'':25s}-> {g_cut:.2f}x less HBM per accepted swap")


def _spmm_plan(T, d_in, K, nm):
    """The fused spmm kernel's actual tiling plan (kernels/spmm._plan)."""
    import sys
    sys.path.insert(0, str(ROOT / "src"))
    from repro.kernels import spmm
    plan = spmm._plan(T, d_in, K, nm, tile_t=spmm.TILE_T,
                      tile_o=spmm.TILE_O, tile_d=spmm.TILE_D,
                      tile_s=spmm.TILE_S)
    plan["T"] = T
    return plan


# VPU lanes do ~8x128 fp32 ops/cycle vs the MXU's 2·128·128 flops/cycle:
# one expansion (masked-add) op costs ~32 dot-flops of machine time.
_VPU_MXU_RATIO = (2 * 128 * 128) / (8 * 128)


def serving_kernel_rows(shapes=((4096, 4096), (14336, 4096),
                                (4096, 14336)),
                        *, t_prefill=2048, t_decode=8, nm=(2, 4),
                        dtype_bytes=2):
    """Analytic table for the fused packed spmm (kernels/spmm).

    Per (layer shape x format x phase), using the kernel's real tiling
    plan: packed HBM weight bytes (vs dense), dense-equivalent dot
    FLOPs, slot-expansion VPU ops, and an MXU-utilization proxy =
    t_dot / (t_dot + t_expand) with the expansion costed at the VPU:MXU
    throughput ratio. The structural story the numbers tell:

    * nm24 slots are column-sorted, so each d-tile owns one static slot
      block — expansion is O(K·TD) per output tile, a d_in/TD-fold
      saving over gathered's full O(K·d_in) slot x d-tile sweep (the
      price gathered pays for unstructured masks, growing with d_in);
    * nm24 packs 2:4 at (dtype + 1 meta byte) per kept value — below
      dense bytes; gathered's int32 columns cost 4 B/kept, so its
      packed stream only beats dense at fp32 — its real decode win is
      compute-side (no densification at tiny T);
    * prefill amortizes: expansion runs once per (T/TT) token stripe,
      so expansion ops *per token* drop ~TT-fold from decode to
      prefill — the same amortization the jnp fallback gets from its
      scatter-once-then-BLAS prefill path.
    """
    n, m = nm
    rows = []
    for d_out, d_in in shapes:
        dense_bytes = d_out * d_in * dtype_bytes
        for fmt in ("nm24", "gathered"):
            K = d_in * n // m
            meta = 1 if fmt == "nm24" else 4        # uint8 idx vs int32 cols
            packed_bytes = d_out * K * (dtype_bytes + meta)
            for phase, T in (("prefill", t_prefill), ("decode", t_decode)):
                p = _spmm_plan(T, d_in, K, nm if fmt == "nm24" else None)
                n_t = -(-T // p["tile_t"])
                n_o = -(-d_out // p["tile_o"])
                n_d = p["Dp"] // p["tile_d"]
                steps = n_t * n_o * p["n_s"] * n_d
                expand_ops = steps * p["tile_s"] * p["tile_o"] * p["tile_d"]
                dot_flops = 2 * T * d_out * p["Dp"]
                mxu_util = dot_flops / (dot_flops
                                        + expand_ops * _VPU_MXU_RATIO)
                rows.append({
                    "fmt": fmt, "phase": phase, "T": T,
                    "d_out": d_out, "d_in": d_in,
                    "tiles": (p["tile_t"], p["tile_o"], p["tile_d"],
                              p["tile_s"]),
                    "packed_bytes": packed_bytes,
                    "bytes_vs_dense": packed_bytes / dense_bytes,
                    "dot_flops": dot_flops,
                    "expand_ops": expand_ops,
                    "expand_per_tok": expand_ops / T,
                    "mxu_util": mxu_util,
                })
    return rows


def print_serving_kernels(rows=None, **kw):
    if rows is None:
        rows = serving_kernel_rows(**kw)
    print("\n=== fused packed spmm (serving kernels, bf16 values, "
          "2:4) ===")
    print(f"{'layer':>12s} {'fmt':>9s} {'phase':>8s} "
          f"{'(TT,TO,TD,TS)':>18s} {'pack MiB':>9s} {'vs dense':>9s} "
          f"{'dot GF':>8s} {'exp Mop':>9s} {'exp/tok':>9s} {'MXU%':>6s}")
    for r in rows:
        shp = f"{r['d_out']}x{r['d_in']}"
        print(f"{shp:>12s} {r['fmt']:>9s} {r['phase']:>8s} "
              f"{str(r['tiles']):>18s} {r['packed_bytes']/2**20:9.1f} "
              f"{r['bytes_vs_dense']:9.2f} {r['dot_flops']/1e9:8.2f} "
              f"{r['expand_ops']/1e6:9.1f} {r['expand_per_tok']/1e6:8.2f}M "
              f"{100*r['mxu_util']:5.1f}%")
    print("  -> nm24: aligned slot blocks cut expansion to O(K·TD)/tile "
          "(d_in/TD fewer ops than gathered) and pack below dense bytes.\n"
          "  -> gathered: O(K·d_in) slot sweep + 4B int32 columns — pays "
          "VPU time and bytes for unstructured masks; its decode win is "
          "avoiding densification at tiny T.\n"
          "  -> prefill amortizes expansion ~TT-fold per token (exp/tok "
          "column): the stripe-resident sub-tiles pay once per TT "
          "tokens.")


def load(mesh: str) -> list[dict]:
    d = DRYRUN / mesh
    if not d.exists():
        return []
    rows = []
    for f in sorted(d.glob("*.json")):
        data = json.loads(f.read_text())
        if data.get("ok"):
            rows.append(data)
    return rows


def fmt_row(r: dict) -> str:
    rf = r["roofline"]
    mem = (r["arg_bytes"] + r["temp_bytes"]) / 2**30
    return (f"{r['arch']:22s} {r['cell']:12s} "
            f"{rf['compute_s']:9.3f} {rf['memory_s']:9.3f} "
            f"{rf['ici_s']:9.3f} {rf['dcn_s']:8.3f}  "
            f"{rf['dominant'][:-2]:>7s} {100*rf['compute_fraction']:5.1f}% "
            f"{rf['useful_flops_ratio']:6.2f} {mem:8.2f}")


HEADER = (f"{'arch':22s} {'cell':12s} {'compute_s':>9s} {'memory_s':>9s} "
          f"{'ici_s':>9s} {'dcn_s':>8s}  {'bound':>7s} {'cmp%':>5s} "
          f"{'useful':>6s} {'GiB/dev':>8s}")


def run(verbose: bool = True) -> dict:
    out = {}
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        out[mesh] = rows
        if verbose and rows:
            print(f"\n=== mesh {mesh} ({len(rows)} cells) ===")
            print(HEADER)
            for r in rows:
                print(fmt_row(r))
    if verbose and out.get("16x16"):
        worst = min(out["16x16"], key=lambda r: r["roofline"]["compute_fraction"])
        print(f"\nworst compute-fraction cell: {worst['arch']} "
              f"{worst['cell']} "
              f"({100*worst['roofline']['compute_fraction']:.1f}%)")
    out["swap_search"] = swap_search_rows()
    if verbose:
        print_swap_search(out["swap_search"])
    out["serving_kernels"] = serving_kernel_rows()
    if verbose:
        print_serving_kernels(out["serving_kernels"])
    return out


if __name__ == "__main__":
    run()
