"""Pallas TPU kernel: Gram accumulation Xᵀ X with fp32 accumulation.

Calibration activations arrive in bf16 on TPU; the Gram matrix must be
accumulated in fp32 (paper §2.1.2 — G is the only state the refinement
needs). The kernel is a (d, d) = (tokens, d)ᵀ (tokens, d) matmul tiled for
the MXU with the token (contraction) dimension innermost in the grid, so
each (TI, TJ) output tile stays resident in VMEM while token chunks stream
through.

Grid: (d/TI, d/TJ, tokens/TK). VMEM per step (defaults 256/256/512):
two bf16 x-tiles 2×256KB + fp32 out tile 256KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xi_ref, xj_ref, out_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xi = xi_ref[...]  # (TK, TI)
    xj = xj_ref[...]  # (TK, TJ)
    out_ref[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("tile_i", "tile_j", "tile_k", "interpret")
)
def gram_xtx_padded(
    x: jnp.ndarray,
    *,
    tile_i: int = 256,
    tile_j: int = 256,
    tile_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Xᵀ X for x: (tokens, d) with tokens % tile_k == 0, d % tile == 0."""
    T, d = x.shape
    assert T % tile_k == 0 and d % tile_i == 0 and d % tile_j == 0
    grid = (d // tile_i, d // tile_j, T // tile_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_k, tile_i), lambda i, j, k: (k, i)),
            pl.BlockSpec((tile_k, tile_j), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tile_i, tile_j), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(x, x)
