"""Exact swap cost algebra from the paper (§2.1.3), 1-swap and k-swap.

Everything here is pure jnp and row-batched: a "row block" is `w, m, c` of
shape (R, d_in) plus the shared Gram matrix G (d_in, d_in). These functions
are the single source of truth for the swap formulas; the Pallas kernels in
``repro.kernels`` and the distributed paths reuse them (or are tested
against them).

Notation (paper Eq. 5/6):
    a_u = 2 w_u c_u + w_u^2 G_uu          ΔL contribution of *pruning* the
                                          currently-kept index u
    b_p = -2 w_p c_p + w_p^2 G_pp         ΔL contribution of *unpruning* the
                                          currently-pruned index p
    dL[u, p] = a_u + b_p - 2 w_u w_p G_up

A mask entry m_j == 1 means the weight is KEPT (unpruned), m_j == 0 pruned,
matching the paper. A swap (u, p) prunes kept index u and keeps pruned
index p, preserving the per-row sparsity level.

Two search families share those formulas:

* ``best_swap_*``  — the jointly-best single swap per row (k = 1).
* ``topk_swaps_*`` — the k best candidate pairs per row from ONE ΔL
  evaluation, amortizing the O(R·d_in²) Gram stream over up to k accepted
  swaps. Candidates are the k best *pruned* indices p by score
  ``min_u ΔL[u, p]`` (each paired with its own argmin u), sorted ascending
  with deterministic (ΔL, p, u) lexicographic tie-break — identical across
  the dense / chunked / N:M / Pallas / gram-sharded implementations, so
  every path commits the same swaps bit-for-bit.
* ``commit_swaps`` / ``commit_swaps_columns`` — greedily apply a
  candidate batch in score order, re-scoring each candidate against the
  *updated* correlation state (the true ΔL after earlier accepted swaps
  in the batch) and rejecting any that went non-improving or infeasible,
  so monotonicity and the incremental loss bookkeeping stay exact. The
  ``columns`` flavor (unstructured default) additionally re-searches the
  best u for each candidate column — O(R·d) per candidate — which is
  what sustains ~k/2 accepts per pass on correlated Grams; the
  candidate-space flavor is O(R·k²) (and runs in-kernel on TPU) and
  serves N:M, whose block search is already cheap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INVALID = jnp.float32(jnp.inf)  # +inf sentinel for masked-out candidates
_BIG_I32 = jnp.int32(2**30)     # index sentinel that loses every tie-break


def correlation_vector(w: jnp.ndarray, m: jnp.ndarray, G: jnp.ndarray) -> jnp.ndarray:
    """c = G ((1 - m) ⊙ w), row-batched.

    w, m: (R, d_in); G: (d_in, d_in) -> c: (R, d_in), fp32.
    """
    wp = ((1.0 - m) * w).astype(jnp.float32)
    return wp @ G.astype(jnp.float32).T  # G symmetric; .T keeps layout intent


def row_loss(w: jnp.ndarray, m: jnp.ndarray, G: jnp.ndarray) -> jnp.ndarray:
    """Exact per-row loss L = (w - m⊙w)^T G (w - m⊙w). (R,)."""
    wp = ((1.0 - m) * w).astype(jnp.float32)
    return jnp.einsum("ri,ij,rj->r", wp, G.astype(jnp.float32), wp)


def swap_scores(w: jnp.ndarray, m: jnp.ndarray, c: jnp.ndarray, g_diag: jnp.ndarray):
    """Per-index swap half-costs (a, b) with infeasible entries pushed to +inf.

    a[r, u]: cost term for pruning currently-kept u   (valid where m==1)
    b[r, p]: cost term for unpruning currently-pruned p (valid where m==0)
    """
    w = w.astype(jnp.float32)
    c = c.astype(jnp.float32)
    quad = (w * w) * g_diag.astype(jnp.float32)
    a = 2.0 * w * c + quad
    b = -2.0 * w * c + quad
    a = jnp.where(m > 0.5, a, INVALID)
    b = jnp.where(m > 0.5, INVALID, b)
    return a, b


def delta_matrix(w, m, c, G):
    """Full ΔL[r, u, p] matrix (reference path — O(R d_in²) memory).

    Infeasible pairs (u not kept, p not pruned) are +inf.
    """
    g_diag = jnp.diagonal(G)
    a, b = swap_scores(w, m, c, g_diag)
    w32 = w.astype(jnp.float32)
    inter = 2.0 * jnp.einsum("ru,rp,up->rup", w32, w32, G.astype(jnp.float32))
    return a[:, :, None] + b[:, None, :] - inter


def best_swap_dense(w, m, c, G):
    """Jointly-best (ΔL*, u*, p*) per row via the dense ΔL matrix.

    Returns (dl, u_idx, p_idx) with shapes (R,), (R,), (R,).
    Reference implementation; production uses the chunked/Pallas paths.
    """
    dl = delta_matrix(w, m, c, G)
    R, d, _ = dl.shape
    flat = dl.reshape(R, d * d)
    idx = jnp.argmin(flat, axis=1)
    best = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0]
    return best, idx // d, idx % d


def best_swap_chunked(w, m, c, G, *, chunk: int = 512):
    """Memory-lean jointly-best swap: stream over p-column chunks of G.

    For each chunk of pruned candidates p, reduce over all u on the fly:
    memory O(R * chunk) instead of O(R * d_in²). Pure jnp (works on any
    backend); the Pallas kernel implements the same contraction tiled for
    VMEM.
    """
    d_in = G.shape[0]
    g_diag = jnp.diagonal(G)
    a, b = swap_scores(w, m, c, g_diag)  # (R, d)
    w32 = w.astype(jnp.float32)
    nchunks = (d_in + chunk - 1) // chunk
    pad = nchunks * chunk - d_in
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)), constant_values=jnp.inf)
        Gp = jnp.pad(G.astype(jnp.float32), ((0, 0), (0, pad)))
        wp = jnp.pad(w32, ((0, 0), (0, pad)))
    else:
        Gp, wp = G.astype(jnp.float32), w32

    best = jnp.full((w.shape[0],), jnp.inf, jnp.float32)
    best_u = jnp.zeros((w.shape[0],), jnp.int32)
    best_p = jnp.zeros((w.shape[0],), jnp.int32)
    # fori-style python loop: nchunks is static, so this unrolls in jit.
    for ci in range(nchunks):
        sl = slice(ci * chunk, (ci + 1) * chunk)
        Gc = Gp[:, sl]                       # (d, chunk)
        # ΔL[r, u, p] for this chunk = a[r,u] + b[r,p] - 2 w_u w_p G_up
        inter = 2.0 * jnp.einsum("ru,rp,up->rup", w32, wp[:, sl], Gc)
        dl = a[:, :, None] + b[:, sl][:, None, :] - inter  # (R, d, chunk)
        flat = dl.reshape(dl.shape[0], -1)
        idx = jnp.argmin(flat, axis=1)
        val = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0]
        u_i = (idx // chunk).astype(jnp.int32)
        p_i = (idx % chunk + ci * chunk).astype(jnp.int32)
        upd = val < best
        best = jnp.where(upd, val, best)
        best_u = jnp.where(upd, u_i, best_u)
        best_p = jnp.where(upd, p_i, best_p)
    return best, best_u, best_p


def best_swap_nm(w, m, c, G, *, block: int):
    """Best within-block swap for N:M sparsity (paper §2.2).

    Swaps are restricted to the same M-block, so only the block-diagonal of
    G is needed: O(d_in · block) per row instead of O(d_in²).
    """
    R, d_in = w.shape
    nb = d_in // block
    g_diag = jnp.diagonal(G)
    a, b = swap_scores(w, m, c, g_diag)            # (R, d)
    a = a.reshape(R, nb, block)
    b = b.reshape(R, nb, block)
    w32 = w.astype(jnp.float32).reshape(R, nb, block)
    # Block-diagonal gather of G: (nb, block, block)
    Gb = _block_diag(G, block)
    inter = 2.0 * jnp.einsum("rnu,rnp,nup->rnup", w32, w32, Gb)
    dl = a[..., :, None] + b[..., None, :] - inter  # (R, nb, block, block)
    flat = dl.reshape(R, nb * block * block)
    idx = jnp.argmin(flat, axis=1)
    val = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0]
    blk = idx // (block * block)
    rem = idx % (block * block)
    u_i = (blk * block + rem // block).astype(jnp.int32)
    p_i = (blk * block + rem % block).astype(jnp.int32)
    return val, u_i, p_i


def _block_diag(G: jnp.ndarray, block: int) -> jnp.ndarray:
    """Extract (nb, block, block) block-diagonal of G."""
    d = G.shape[0]
    nb = d // block
    G4 = G.astype(jnp.float32).reshape(nb, block, nb, block)
    idx = jnp.arange(nb)
    return G4[idx, :, idx, :]


# ---------------------------------------------------------------------------
# k-swap candidate search
# ---------------------------------------------------------------------------
#
# A "candidate batch" is the k best (u, p) pairs per row extracted from one
# ΔL evaluation: for every pruned index p the best kept u is found
# (``min_u ΔL[u, p]``, ties to the lowest u), then the k best p columns are
# kept (ties to the lowest p). Distinct-p candidates maximize the number of
# independently-committable swaps per batch — two candidates sharing p can
# never both be accepted. All implementations (dense / chunked / N:M /
# Pallas kernel / gram-sharded) return bit-identical candidate lists.


def _merge_topk(vals, ps, us, new_vals, new_ps, new_us, k: int):
    """Merge two per-row candidate lists, keep the k best by (ΔL, p) lex."""
    v = jnp.concatenate([vals, new_vals], axis=1)
    p = jnp.concatenate([ps, new_ps], axis=1)
    u = jnp.concatenate([us, new_us], axis=1)
    v, p, u = jax.lax.sort((v, p, u), dimension=1, num_keys=2, is_stable=True)
    return v[:, :k], p[:, :k], u[:, :k]


def topk_swaps_dense(w, m, c, G, *, k: int):
    """k best candidate swaps per row via the dense ΔL matrix.

    Returns (dl, u, p) each (R, k), sorted ascending by ΔL; rows with fewer
    than k feasible pairs pad with +inf entries (rejected at commit time).
    Reference path — O(R d_in²) memory, small d only.
    """
    g_diag = jnp.diagonal(G)
    a, b = swap_scores(w, m, c, g_diag)
    w32 = w.astype(jnp.float32)
    # explicit broadcast (not einsum): the exact multiply order the Pallas
    # kernel uses, so candidate ΔL values are bit-identical across paths
    inter = 2.0 * (w32[:, :, None] * w32[:, None, :]) * (
        G.astype(jnp.float32)[None, :, :])
    dl = a[:, :, None] + b[:, None, :] - inter  # (R, d, d) +inf infeasible
    d = dl.shape[2]
    vals_p = jnp.min(dl, axis=1)                # (R, d) best over u, per p
    u_p = jnp.argmin(dl, axis=1).astype(jnp.int32)   # ties -> lowest u
    neg, p_idx = jax.lax.top_k(-vals_p, min(k, d))   # ties -> lowest p
    u_idx = jnp.take_along_axis(u_p, p_idx, axis=1)
    return -neg, u_idx, p_idx.astype(jnp.int32)


def topk_swaps_chunked(w, m, c, G, *, k: int, chunk: int = 512):
    """k best candidate swaps per row, streaming over p-column chunks of G.

    Memory O(R·chunk) like ``best_swap_chunked``; one full G stream yields
    up to k committable swaps instead of one. Bit-identical candidate
    lists to ``topk_swaps_dense`` (same (ΔL, p, u) tie-break).
    """
    R, d_in = w.shape
    k = min(k, d_in)
    g_diag = jnp.diagonal(G)
    a, b = swap_scores(w, m, c, g_diag)         # (R, d)
    w32 = w.astype(jnp.float32)
    nchunks = (d_in + chunk - 1) // chunk
    pad = nchunks * chunk - d_in
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)), constant_values=jnp.inf)
        Gp = jnp.pad(G.astype(jnp.float32), ((0, 0), (0, pad)))
        wp = jnp.pad(w32, ((0, 0), (0, pad)))
    else:
        Gp, wp = G.astype(jnp.float32), w32

    best_v = jnp.full((R, k), jnp.inf, jnp.float32)
    best_p = jnp.full((R, k), _BIG_I32, jnp.int32)
    best_u = jnp.zeros((R, k), jnp.int32)
    for ci in range(nchunks):                   # static: unrolls in jit
        sl = slice(ci * chunk, (ci + 1) * chunk)
        inter = 2.0 * (w32[:, :, None] * wp[:, sl][:, None, :]) * (
            Gp[:, sl][None, :, :])              # kernel multiply order
        dl = a[:, :, None] + b[:, sl][:, None, :] - inter   # (R, d, chunk)
        vals_p = jnp.min(dl, axis=1)                        # (R, chunk)
        u_p = jnp.argmin(dl, axis=1).astype(jnp.int32)
        kk = min(k, chunk)
        neg, p_loc = jax.lax.top_k(-vals_p, kk)
        u_c = jnp.take_along_axis(u_p, p_loc, axis=1)
        p_c = p_loc.astype(jnp.int32) + ci * chunk
        best_v, best_p, best_u = _merge_topk(
            best_v, best_p, best_u, -neg, p_c, u_c, k)
    return best_v, best_u, best_p


def topk_swaps_nm(w, m, c, G, *, block: int, k: int):
    """k best within-block candidate swaps for N:M sparsity.

    Same block-diagonal contraction as ``best_swap_nm`` — only
    O(d_in·block) of G is touched per row.
    """
    R, d_in = w.shape
    nb = d_in // block
    k = min(k, d_in)
    g_diag = jnp.diagonal(G)
    a, b = swap_scores(w, m, c, g_diag)
    a = a.reshape(R, nb, block)
    b = b.reshape(R, nb, block)
    w32 = w.astype(jnp.float32).reshape(R, nb, block)
    Gb = _block_diag(G, block)
    inter = 2.0 * (w32[..., :, None] * w32[..., None, :]) * Gb[None]
    dl = a[..., :, None] + b[..., None, :] - inter  # (R, nb, block, block)
    vals_p = jnp.min(dl, axis=2).reshape(R, d_in)   # global p order
    u_loc = jnp.argmin(dl, axis=2).astype(jnp.int32)            # (R, nb, B)
    u_glob = (u_loc + block * jnp.arange(nb, dtype=jnp.int32)[None, :, None]
              ).reshape(R, d_in)
    neg, p_idx = jax.lax.top_k(-vals_p, k)
    u_idx = jnp.take_along_axis(u_glob, p_idx, axis=1)
    return -neg, u_idx, p_idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# k-swap commit: greedy apply with exact re-scoring in candidate space
# ---------------------------------------------------------------------------


def gather_candidate_stats(w, c, G, u, p):
    """Gather the per-candidate inputs the commit decision loop needs.

    w, c: (R, d); G: (d, d); u, p: (R, k) int32. Returns
    (wu, wp, cu, cp, Suu, Sup, Spp) where S** are the (R, k, k) candidate
    sub-Grams  Suu[i, j] = G[u_i, u_j],  Sup[i, j] = G[u_i, p_j],
    Spp[i, j] = G[p_i, p_j] — everything the sequential re-scoring touches,
    O(R·k²) instead of O(R·d²).
    """
    w32 = w.astype(jnp.float32)
    G32 = G.astype(jnp.float32)
    wu = jnp.take_along_axis(w32, u, axis=1)
    wp = jnp.take_along_axis(w32, p, axis=1)
    cu = jnp.take_along_axis(c, u, axis=1)
    cp = jnp.take_along_axis(c, p, axis=1)
    Suu = G32[u[:, :, None], u[:, None, :]]
    Sup = G32[u[:, :, None], p[:, None, :]]
    Spp = G32[p[:, :, None], p[:, None, :]]
    return wu, wp, cu, cp, Suu, Sup, Spp


def commit_decisions(wu, wp, cu, cp, Suu, Sup, Spp, u, p, valid, *,
                     eps: float, k: int):
    """Sequential greedy accept/reject over a candidate batch, in candidate
    space only (no O(d) state touched).

    Candidates are visited in list order (ascending searched ΔL). Each is
    re-scored against the correlation values updated by every *earlier
    accepted* swap in the batch — the true ΔL of applying it now — and
    accepted iff it is still feasible (its u not yet pruned, its p not yet
    unpruned by this batch) and still improving (ΔL < -eps). Because the u
    candidates come from the originally-kept set and the p candidates from
    the originally-pruned set, feasibility reduces to index-collision
    checks within the batch.

    Pure jnp on (R, k)-shaped values — shared verbatim by the single-device
    commit, the gram-sharded commit (on a psum-built sub-Gram) and the
    Pallas commit kernel, which keeps every path bit-identical.

    Returns (acc, dls): acc (R, k) float 0/1 accept flags, dls (R, k)
    exact re-scored ΔL (0 where rejected).
    """
    u_dead = jnp.zeros_like(wu)
    p_dead = jnp.zeros_like(wp)
    accs, dls = [], []
    # every op below keeps a (R, 1) or (R, k) shape — the loop body is
    # executed verbatim inside the Pallas commit kernel (kernels/swap_topk)
    for t in range(k):                           # k static: unrolled
        wu_t, wp_t = wu[:, t:t + 1], wp[:, t:t + 1]
        suu_t = Suu[:, :, t]                     # (R, k) column t
        sup_col_t = Sup[:, :, t]
        sup_row_t = Sup[:, t, :]
        spp_t = Spp[:, :, t]
        a_t = 2.0 * wu_t * cu[:, t:t + 1] + (wu_t * wu_t) * suu_t[:, t:t + 1]
        b_t = (-2.0 * wp_t * cp[:, t:t + 1]
               + (wp_t * wp_t) * spp_t[:, t:t + 1])
        dl_t = a_t + b_t - 2.0 * (wu_t * wp_t) * sup_col_t[:, t:t + 1]
        ok = ((valid[:, t:t + 1] > 0.5) & (u_dead[:, t:t + 1] < 0.5)
              & (p_dead[:, t:t + 1] < 0.5) & (dl_t < -eps))
        okf = ok.astype(jnp.float32)             # (R, 1)
        # Eq. 6 restricted to candidate positions:
        #   c[u_j] += w_u G[u_j, u_t] - w_p G[u_j, p_t]
        #   c[p_j] += w_u G[u_t, p_j] - w_p G[p_j, p_t]
        cu = cu + okf * (wu_t * suu_t - wp_t * sup_col_t)
        cp = cp + okf * (wu_t * sup_row_t - wp_t * spp_t)
        u_dead = jnp.maximum(
            u_dead, okf * (u == u[:, t:t + 1]).astype(jnp.float32))
        p_dead = jnp.maximum(
            p_dead, okf * (p == p[:, t:t + 1]).astype(jnp.float32))
        accs.append(okf)
        dls.append(jnp.where(ok, dl_t, 0.0))
    return jnp.concatenate(accs, axis=1), jnp.concatenate(dls, axis=1)


def apply_commits(w, m, c, G, acc, dls, u, p):
    """Apply a decided candidate batch: mask flips + full-width Eq. 6.

    acc, dls: ``commit_decisions`` output. One rank-1 c-update per accepted
    swap — O(accepted·R·d) gather bytes, amortized against the O(R·d²)
    search that produced the batch. Returns (m', c', dl_sum, n_accepted).
    """
    R, k = acc.shape
    w32 = w.astype(jnp.float32)
    G32 = G.astype(jnp.float32)
    for t in range(k):                           # static unroll, k small
        sel = acc[:, t][:, None]
        wu_t = jnp.take_along_axis(w32, u[:, t:t + 1], axis=1)
        wp_t = jnp.take_along_axis(w32, p[:, t:t + 1], axis=1)
        gu = G32[:, u[:, t]].T                   # (R, d) columns G_{:, u_t}
        gp = G32[:, p[:, t]].T
        c = c + sel * (wu_t * gu - wp_t * gp)
        flip = (jax.nn.one_hot(p[:, t], m.shape[1], dtype=m.dtype)
                - jax.nn.one_hot(u[:, t], m.shape[1], dtype=m.dtype))
        m = m + sel.astype(m.dtype) * flip
    return m, c, jnp.sum(dls, axis=1), jnp.sum(acc, axis=1).astype(jnp.int32)


def commit_swaps_columns(w, m, c, G, dl, p_idx, *, eps: float = 0.0):
    """Greedily commit the k best candidate COLUMNS per row, re-pairing u.

    The production unstructured commit. ``p_idx`` (R, k): the stale
    search's top-k pruned columns (ascending stale ΔL; ``dl`` is only
    consulted for validity of the +inf tail). For each column in order,
    the best kept u is re-searched EXACTLY against the current (m, c) —
    an O(R·d) column-restricted argmin, d/k× cheaper than the full
    search — so a candidate whose stale pairing died from an earlier
    accept in the batch re-pairs instead of being discarded. Accepted iff
    the column is still pruned and the re-scored ΔL < -eps; every accept
    applies the exact Eq. 6 rank-1 update before the next candidate.

    Deeper per-pass chains than the candidate-space ``commit_swaps``
    (whose re-scoring can only reject): on correlated Grams this is the
    difference between ~1.5 and ~k/2 accepted swaps per O(R·d²) search.

    If a pass accepts nothing, candidate 0 — the stale global argmin,
    re-scored against an unchanged state — was non-improving, so the row
    is a certified 1-swap fixed point; convergence detection is exactly
    the 1-swap loop's.

    Returns (m', c', dl_sum (R,), n_accepted (R,) int32).
    """
    R, k = p_idx.shape
    d_in = w.shape[1]
    w32 = w.astype(jnp.float32)
    G32 = G.astype(jnp.float32)
    c = c.astype(jnp.float32)
    g_diag = jnp.diagonal(G32)
    valid = jnp.isfinite(dl)
    p_idx = jnp.clip(p_idx, 0, d_in - 1)
    rows = jnp.arange(R)
    dsum = jnp.zeros(R, jnp.float32)
    nacc = jnp.zeros(R, jnp.int32)
    for t in range(k):                           # static unroll, k small
        pt = p_idx[:, t]
        gcol = G32[:, pt].T                                  # (R, d)
        wpt = jnp.take_along_axis(w32, pt[:, None], 1)[:, 0]
        cpt = jnp.take_along_axis(c, pt[:, None], 1)[:, 0]
        b_t = -2.0 * wpt * cpt + (wpt * wpt) * g_diag[pt]    # (R,)
        a = 2.0 * w32 * c + (w32 * w32) * g_diag[None, :]
        a = jnp.where(m > 0.5, a, INVALID)
        dl_u = a + b_t[:, None] - 2.0 * (w32 * wpt[:, None]) * gcol
        ui = jnp.argmin(dl_u, axis=1)                        # ties -> low u
        dl_t = jnp.take_along_axis(dl_u, ui[:, None], 1)[:, 0]
        still_pruned = jnp.take_along_axis(m, pt[:, None], 1)[:, 0] < 0.5
        ok = (dl_t < -eps) & still_pruned & valid[:, t] & jnp.isfinite(dl_t)
        okf = ok.astype(jnp.float32)[:, None]
        wut = jnp.take_along_axis(w32, ui[:, None], 1)
        gu = G32[:, ui].T
        c = c + okf * (wut * gu - wpt[:, None] * gcol)
        m = m.at[rows, ui].set(jnp.where(ok, 0.0, m[rows, ui]))
        m = m.at[rows, pt].set(jnp.where(ok, 1.0, m[rows, pt]))
        dsum = dsum + jnp.where(ok, dl_t, 0.0)
        nacc = nacc + ok.astype(jnp.int32)
    return m, c, dsum, nacc


def commit_swaps(w, m, c, G, dl, u_idx, p_idx, *, eps: float = 0.0):
    """Greedily commit a k-candidate batch per row in candidate space.

    dl, u_idx, p_idx: a ``topk_swaps_*`` candidate list, ascending by ΔL
    (+inf = no candidate). Candidates are re-scored in order against the
    correlation state updated by earlier accepts in the batch (the true
    ΔL of each swap as applied), and any that turned non-improving or
    infeasible are rejected — the loss decrease is exact and monotone,
    up to k swaps per O(R·d²) search. The sequential loop runs entirely
    in O(R·k²) candidate space (``commit_decisions`` — also available
    in-kernel, ``kernels.swap_topk.swap_commit_padded``); this is the
    N:M commit and the cheap unstructured variant, while
    ``commit_swaps_columns`` (which re-pairs u per candidate) is the
    unstructured default.

    Returns (m', c', dl_sum (R,), n_accepted (R,) int32).
    """
    k = dl.shape[1]
    c = c.astype(jnp.float32)
    valid = jnp.isfinite(dl).astype(jnp.float32)
    # +inf-padded candidates carry an out-of-range index sentinel from the
    # kernel path; clamp for the gathers (they are masked out by `valid`)
    d_in = w.shape[1]
    u_idx = jnp.clip(u_idx, 0, d_in - 1)
    p_idx = jnp.clip(p_idx, 0, d_in - 1)
    wu, wp, cu, cp, Suu, Sup, Spp = gather_candidate_stats(w, c, G, u_idx,
                                                           p_idx)
    acc, dls = commit_decisions(wu, wp, cu, cp, Suu, Sup, Spp, u_idx, p_idx,
                                valid, eps=eps, k=k)
    return apply_commits(w, m, c, G, acc, dls, u_idx, p_idx)


def apply_swap(w, m, c, G, dl, u_idx, p_idx, *, eps: float = 0.0):
    """Apply accepted swaps row-batched; rows with dl >= -eps are no-ops.

    Returns (m', c', accepted) — Eq. 6 correlation update:
        c ← c + w_u G_{:,u} − w_p G_{:,p}
    """
    accepted = dl < -eps
    R, d_in = m.shape
    rows = jnp.arange(R)
    G32 = G.astype(jnp.float32)
    gu = G32[:, u_idx].T  # (R, d_in) columns G_{:, u*}
    gp = G32[:, p_idx].T
    wu = jnp.take_along_axis(w, u_idx[:, None], axis=1)[:, 0].astype(jnp.float32)
    wp = jnp.take_along_axis(w, p_idx[:, None], axis=1)[:, 0].astype(jnp.float32)
    c_new = c + wu[:, None] * gu - wp[:, None] * gp
    m_new = m.at[rows, u_idx].set(0.0).at[rows, p_idx].set(1.0)
    acc = accepted[:, None]
    return (
        jnp.where(acc, m_new, m),
        jnp.where(acc, c_new, c),
        accepted,
    )
