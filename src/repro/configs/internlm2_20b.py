"""internlm2-20b [dense] — llama-style GQA.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544
[arXiv:2403.17297; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    grad_accum=2,             # fits train_4k in 16 GB HBM
    mlp="gated",
    act="silu",
)

TINY = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=256, dtype="float32",
)
